//! Single-case measurement: build stack + engine, drive warm-up and timed
//! sequences through the [`GradientEngine`] trait, read wall-clock and the
//! per-phase / per-layer op counters.

use super::{BenchCase, CaseResult};
use crate::metrics::ops::NUM_PHASES;
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use crate::rtrl::{GradientEngine, Target};
use crate::sparse::MaskPattern;
use crate::train::build_engine;
use crate::util::Pcg64;
use std::time::Instant;

/// Input dimensionality of the bench network (the paper's spiral task shape).
const BENCH_N_IN: usize = 2;
/// Output classes of the bench readout.
const BENCH_N_OUT: usize = 2;
/// Pseudo-derivative height γ / support ε (config defaults).
const BENCH_GAMMA: f32 = 0.3;
const BENCH_EPS: f32 = 0.2;

/// Measure one case. Deterministic for a given `BenchCase` (weights, masks
/// and the input stream all derive from `case.seed`); wall-time obviously
/// varies with the host.
pub fn run_case(case: &BenchCase) -> CaseResult {
    let n = case.hidden;
    let mut rng = Pcg64::new(0xbe2c_0001 ^ (case.seed.wrapping_mul(0x9e37_79b9)));
    let mut cells = Vec::with_capacity(case.layers);
    for l in 0..case.layers {
        let n_in = if l == 0 { BENCH_N_IN } else { n };
        let mask = if case.param_sparsity > 0.0 {
            Some(MaskPattern::random(n, n, 1.0 - case.param_sparsity, &mut rng))
        } else {
            None
        };
        cells.push(RnnCell::egru(n, n_in, case.theta, BENCH_GAMMA, BENCH_EPS, mask, &mut rng));
    }
    let net = LayerStack::new(cells);
    let mut readout = Readout::new(BENCH_N_OUT, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, BENCH_N_OUT);
    let mut engine = build_engine(case.engine, &net, BENCH_N_OUT);
    engine.set_threads(case.threads);

    // Fixed input stream; one class target at the end of each sequence so
    // the gradient-combine phase is exercised like real training.
    let mut xrng = Pcg64::new(0x5eed_0000 ^ case.seed);
    let inputs: Vec<Vec<f32>> = (0..case.timesteps)
        .map(|_| (0..BENCH_N_IN).map(|_| xrng.normal()).collect())
        .collect();
    let mut targets = vec![Target::None; case.timesteps];
    targets[case.timesteps - 1] = Target::Class(0);

    let mut ops = OpCounter::new();
    for _ in 0..case.warmup_sequences {
        engine.run_sequence(&net, &mut readout, &mut loss, &inputs, &targets, &mut ops);
    }
    readout.zero_grads();

    let before = ops.clone();
    let mut active_unit_steps = 0usize;
    let mut deriv_unit_steps = 0usize;
    let t0 = Instant::now();
    for _ in 0..case.sequences {
        let summary =
            engine.run_sequence(&net, &mut readout, &mut loss, &inputs, &targets, &mut ops);
        active_unit_steps += summary.active_unit_steps;
        deriv_unit_steps += summary.deriv_unit_steps;
        std::hint::black_box(engine.grads()[0]);
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let delta = ops.since(&before);

    let steps = (case.sequences * case.timesteps) as u64;
    let unit_steps = (steps as usize * net.total_units()) as f64;
    let mut macs_per_step = [0u64; NUM_PHASES];
    for ph in Phase::all() {
        macs_per_step[ph.index()] = delta.macs_in(ph) / steps;
    }
    let macs_per_step_per_layer: Vec<u64> =
        (0..case.layers).map(|l| delta.layer_total_macs(l) / steps).collect();
    let words_per_step_per_layer: Vec<u64> =
        (0..case.layers).map(|l| delta.layer_total_words(l) / steps).collect();
    let ns_per_step = wall_ns as f64 / steps as f64;
    CaseResult {
        engine: case.engine.name(),
        hidden: n,
        layers: case.layers,
        param_sparsity: case.param_sparsity,
        omega_tilde: net.omega_tilde(),
        p: net.p(),
        timesteps: case.timesteps,
        sequences: case.sequences,
        threads: case.threads,
        wall_ns,
        ns_per_step,
        steps_per_sec: if ns_per_step > 0.0 { 1e9 / ns_per_step } else { 0.0 },
        seqs_per_sec: if wall_ns > 0 { case.sequences as f64 * 1e9 / wall_ns as f64 } else { 0.0 },
        macs_per_step,
        macs_per_step_total: delta.total_macs() / steps,
        words_per_step_total: delta.total_words() / steps,
        macs_per_step_per_layer,
        words_per_step_per_layer,
        state_memory_words: engine.state_memory_words(),
        alpha_tilde: active_unit_steps as f64 / unit_steps,
        beta_tilde: deriv_unit_steps as f64 / unit_steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;

    fn case(engine: AlgorithmKind, omega: f32) -> BenchCase {
        BenchCase {
            engine,
            hidden: 8,
            layers: 1,
            param_sparsity: omega,
            timesteps: 6,
            sequences: 2,
            warmup_sequences: 1,
            theta: 0.1,
            threads: 1,
            seed: 7,
        }
    }

    /// The threads knob changes wall-clock only: per-phase and per-layer op
    /// counts are identical between a serial and a 2-worker run. At this
    /// tiny size the panels sit below the engine's parallel threshold, so
    /// this pins the *grid plumbing*; the threaded row update itself is
    /// exercised above-threshold by `tests/jacobian_slab.rs` and by the CI
    /// arm's `--hidden 64` invariance bench.
    #[test]
    fn intra_step_threads_do_not_change_op_counts() {
        for kind in [AlgorithmKind::RtrlBoth, AlgorithmKind::RtrlActivity] {
            let serial = run_case(&case(kind, 0.5));
            let mut c2 = case(kind, 0.5);
            c2.threads = 2;
            let threaded = run_case(&c2);
            assert_eq!(serial.macs_per_step, threaded.macs_per_step, "{kind:?}");
            assert_eq!(
                serial.macs_per_step_per_layer, threaded.macs_per_step_per_layer,
                "{kind:?}"
            );
            assert_eq!(serial.words_per_step_total, threaded.words_per_step_total);
            assert_eq!(serial.state_memory_words, threaded.state_memory_words);
            assert_eq!(serial.alpha_tilde.to_bits(), threaded.alpha_tilde.to_bits());
            assert_eq!(serial.beta_tilde.to_bits(), threaded.beta_tilde.to_bits());
        }
    }

    #[test]
    fn deterministic_op_counts() {
        let a = run_case(&case(AlgorithmKind::RtrlBoth, 0.5));
        let b = run_case(&case(AlgorithmKind::RtrlBoth, 0.5));
        assert_eq!(a.macs_per_step, b.macs_per_step);
        assert_eq!(a.state_memory_words, b.state_memory_words);
        assert!((a.alpha_tilde - b.alpha_tilde).abs() < 1e-12);
    }

    #[test]
    fn every_engine_kind_measures() {
        for kind in AlgorithmKind::all() {
            let r = run_case(&case(kind, 0.5));
            assert_eq!(r.engine, kind.name());
            assert!(r.macs_per_step_total > 0, "{}: zero MACs", r.engine);
            assert!(r.wall_ns > 0);
        }
    }

    #[test]
    fn param_sparsity_reduces_tracked_columns_cost() {
        let dense = run_case(&case(AlgorithmKind::RtrlParam, 0.0));
        let sparse = run_case(&case(AlgorithmKind::RtrlParam, 0.8));
        assert!(
            sparse.macs_per_step_total < dense.macs_per_step_total,
            "ω=0.8 {} !< ω=0 {}",
            sparse.macs_per_step_total,
            dense.macs_per_step_total
        );
        assert!(sparse.omega_tilde < 0.5);
    }

    #[test]
    fn depth2_case_measures_every_engine() {
        for kind in AlgorithmKind::all() {
            let mut c = case(kind, 0.5);
            c.layers = 2;
            let r = run_case(&c);
            assert_eq!(r.layers, 2);
            assert_eq!(r.macs_per_step_per_layer.len(), 2);
            assert!(r.p > run_case(&case(kind, 0.5)).p, "depth should add params");
            assert!(r.macs_per_step_per_layer.iter().sum::<u64>() > 0);
        }
    }
}
