//! Single-case measurement: build stack + engine, drive warm-up and timed
//! sequences, read wall-clock and the per-phase / per-layer op counters.
//!
//! Three execution paths share one accounting tail:
//! * **batched** — `rtrl-param` cases run through the shared-weight
//!   [`BatchedSparse`] engine at the case's lane width, *including width 1*,
//!   so `--batch 1` vs `--batch 8` compares the same machinery and is
//!   bit-identical by construction (gradient fingerprints and op counters
//!   diff equal in CI);
//! * **serial lanes** — other engines at `batch > 1` step each lane
//!   sequentially through one engine (shared weights, no fusion): the wall
//!   clock covers every lane, lane 0's ops/gradient are reported;
//! * **solo** — the classic single-lane path, unchanged.
//!
//! Lane 0 always consumes exactly the stream a width-1 run would, so its
//! gradient fingerprint is invariant across batch widths and thread counts.

use super::{BenchCase, CaseResult};
use crate::config::AlgorithmKind;
use crate::metrics::ops::NUM_PHASES;
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use crate::rtrl::{BatchedSparse, GradientEngine, Target};
use crate::sparse::MaskPattern;
use crate::train::build_engine;
use crate::util::Pcg64;
use std::time::Instant;

/// Input dimensionality of the bench network (the paper's spiral task shape).
const BENCH_N_IN: usize = 2;
/// Output classes of the bench readout.
const BENCH_N_OUT: usize = 2;
/// Pseudo-derivative height γ / support ε (config defaults).
const BENCH_GAMMA: f32 = 0.3;
const BENCH_EPS: f32 = 0.2;

/// FNV-1a folded over the f32 bit patterns of a gradient vector — the
/// cheap bit-exactness witness the CI invariance arms diff. Serialized as
/// a decimal string (not a JSON number) so f64-based parsers keep all 64
/// bits.
pub fn grad_fingerprint(grads: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in grads {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Lane `lane`'s fixed input stream. Lane 0 is exactly the stream the
/// pre-batch bench drew (`0x5eed_0000 ^ seed`); later lanes shift the
/// stream id into the high word so lanes never collide for any seed.
fn lane_inputs(case: &BenchCase, lane: usize) -> Vec<Vec<f32>> {
    let mut xrng = Pcg64::new((0x5eed_0000 ^ case.seed) ^ ((lane as u64) << 32));
    (0..case.timesteps)
        .map(|_| (0..BENCH_N_IN).map(|_| xrng.normal()).collect())
        .collect()
}

/// One class target at the end of each sequence so the gradient-combine
/// phase is exercised like real training.
fn bench_targets(timesteps: usize) -> Vec<Target<'static>> {
    let mut targets = vec![Target::None; timesteps];
    targets[timesteps - 1] = Target::Class(0);
    targets
}

/// Measure one case. Deterministic for a given `BenchCase` (weights, masks
/// and every lane's input stream all derive from `case.seed`); wall-time
/// obviously varies with the host.
pub fn run_case(case: &BenchCase) -> CaseResult {
    let n = case.hidden;
    let mut rng = Pcg64::new(0xbe2c_0001 ^ (case.seed.wrapping_mul(0x9e37_79b9)));
    let mut cells = Vec::with_capacity(case.layers);
    for l in 0..case.layers {
        let n_in = if l == 0 { BENCH_N_IN } else { n };
        let mask = if case.param_sparsity > 0.0 {
            Some(MaskPattern::random(n, n, 1.0 - case.param_sparsity, &mut rng))
        } else {
            None
        };
        cells.push(RnnCell::egru(n, n_in, case.theta, BENCH_GAMMA, BENCH_EPS, mask, &mut rng));
    }
    let net = LayerStack::new(cells);
    let readout = Readout::new(BENCH_N_OUT, net.top_n(), &mut rng);
    let loss = Loss::new(LossKind::CrossEntropy, BENCH_N_OUT);
    if case.engine == AlgorithmKind::RtrlParam {
        run_case_batched(case, &net, &readout, &loss)
    } else if case.batch > 1 {
        run_case_serial_lanes(case, &net, readout, loss)
    } else {
        run_case_solo(case, &net, readout, loss)
    }
}

/// Shared accounting tail: per-step op attribution divides by **lane-0**
/// steps (ops are per-lane by contract), wall-clock rates divide by
/// lane-steps across the whole batch, so `ns_per_step` at width B > 1
/// drops exactly when batching amortizes real work.
#[allow(clippy::too_many_arguments)]
fn finish(
    case: &BenchCase,
    net: &LayerStack,
    delta: &OpCounter,
    wall_ns: u64,
    active_unit_steps: usize,
    deriv_unit_steps: usize,
    grad_fp: u64,
    state_memory_words: usize,
) -> CaseResult {
    let batch = case.batch.max(1);
    let steps = (case.sequences * case.timesteps) as u64;
    let lane_steps = steps * batch as u64;
    let unit_steps = (steps as usize * net.total_units()) as f64;
    let mut macs_per_step = [0u64; NUM_PHASES];
    for ph in Phase::all() {
        macs_per_step[ph.index()] = delta.macs_in(ph) / steps;
    }
    let macs_per_step_per_layer: Vec<u64> =
        (0..case.layers).map(|l| delta.layer_total_macs(l) / steps).collect();
    let words_per_step_per_layer: Vec<u64> =
        (0..case.layers).map(|l| delta.layer_total_words(l) / steps).collect();
    let ns_per_step = wall_ns as f64 / lane_steps as f64;
    CaseResult {
        engine: case.engine.name(),
        hidden: case.hidden,
        layers: case.layers,
        param_sparsity: case.param_sparsity,
        omega_tilde: net.omega_tilde(),
        p: net.p(),
        timesteps: case.timesteps,
        sequences: case.sequences,
        threads: case.threads,
        batch,
        grad_fp,
        wall_ns,
        ns_per_step,
        steps_per_sec: if ns_per_step > 0.0 { 1e9 / ns_per_step } else { 0.0 },
        seqs_per_sec: if wall_ns > 0 {
            (case.sequences * batch) as f64 * 1e9 / wall_ns as f64
        } else {
            0.0
        },
        macs_per_step,
        macs_per_step_total: delta.total_macs() / steps,
        words_per_step_total: delta.total_words() / steps,
        macs_per_step_per_layer,
        words_per_step_per_layer,
        state_memory_words,
        alpha_tilde: active_unit_steps as f64 / unit_steps,
        beta_tilde: deriv_unit_steps as f64 / unit_steps,
    }
}

/// The classic single-lane path, through the [`GradientEngine`] trait.
fn run_case_solo(
    case: &BenchCase,
    net: &LayerStack,
    mut readout: Readout,
    mut loss: Loss,
) -> CaseResult {
    let mut engine = build_engine(case.engine, net, BENCH_N_OUT);
    engine.set_threads(case.threads);
    let inputs = lane_inputs(case, 0);
    let targets = bench_targets(case.timesteps);

    let mut ops = OpCounter::new();
    for _ in 0..case.warmup_sequences {
        engine.run_sequence(net, &mut readout, &mut loss, &inputs, &targets, &mut ops);
    }
    readout.zero_grads();

    let before = ops.clone();
    let mut active_unit_steps = 0usize;
    let mut deriv_unit_steps = 0usize;
    let t0 = Instant::now();
    for _ in 0..case.sequences {
        let summary =
            engine.run_sequence(net, &mut readout, &mut loss, &inputs, &targets, &mut ops);
        active_unit_steps += summary.active_unit_steps;
        deriv_unit_steps += summary.deriv_unit_steps;
        std::hint::black_box(engine.grads()[0]);
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let grad_fp = grad_fingerprint(engine.grads());
    finish(
        case,
        net,
        &ops.since(&before),
        wall_ns,
        active_unit_steps,
        deriv_unit_steps,
        grad_fp,
        engine.state_memory_words(),
    )
}

/// One sequence through the batched engine; returns lane 0's (active,
/// deriv) unit-step totals.
fn drive_batched_sequence(
    batched: &mut BatchedSparse,
    inputs: &[Vec<Vec<f32>>],
    targets: &[Target<'_>],
    readouts: &mut [Readout],
    losses: &mut [Loss],
    ops: &mut [OpCounter],
) -> (usize, usize) {
    let b = batched.batch();
    batched.begin_sequence();
    let (mut active, mut deriv) = (0usize, 0usize);
    for (t, tg) in targets.iter().enumerate() {
        let xs: Vec<&[f32]> = (0..b).map(|s| inputs[s][t].as_slice()).collect();
        let tgs: Vec<Target<'_>> = vec![*tg; b];
        let mut rr: Vec<&mut Readout> = readouts.iter_mut().collect();
        let mut ll: Vec<&mut Loss> = losses.iter_mut().collect();
        let mut oo: Vec<&mut OpCounter> = ops.iter_mut().collect();
        let results = batched.step(&xs, &tgs, &mut rr, &mut ll, &mut oo);
        active += results[0].active_units;
        deriv += results[0].deriv_units;
    }
    batched.end_sequence();
    (active, deriv)
}

/// `rtrl-param` at any width: the shared-weight batched engine, lanes
/// differing only in their input streams (every lane's readout starts as a
/// clone of the shared one — the serving-fleet shape). Reported ops and
/// gradient are lane 0's; `state_memory_words` stays the *per-session*
/// footprint so the column remains comparable across engines and widths.
fn run_case_batched(
    case: &BenchCase,
    net: &LayerStack,
    readout: &Readout,
    loss: &Loss,
) -> CaseResult {
    let b = case.batch.max(1);
    let mut batched = BatchedSparse::new(net, BENCH_N_OUT, b);
    batched.set_threads(case.threads);
    let mut readouts: Vec<Readout> = (0..b).map(|_| readout.clone()).collect();
    let mut losses: Vec<Loss> = (0..b).map(|_| loss.clone()).collect();
    let mut ops: Vec<OpCounter> = (0..b).map(|_| OpCounter::new()).collect();
    let inputs: Vec<Vec<Vec<f32>>> = (0..b).map(|s| lane_inputs(case, s)).collect();
    let targets = bench_targets(case.timesteps);

    for _ in 0..case.warmup_sequences {
        drive_batched_sequence(&mut batched, &inputs, &targets, &mut readouts, &mut losses, &mut ops);
    }
    for r in &mut readouts {
        r.zero_grads();
    }

    let before = ops[0].clone();
    let mut active_unit_steps = 0usize;
    let mut deriv_unit_steps = 0usize;
    let t0 = Instant::now();
    for _ in 0..case.sequences {
        let (a, d) =
            drive_batched_sequence(&mut batched, &inputs, &targets, &mut readouts, &mut losses, &mut ops);
        active_unit_steps += a;
        deriv_unit_steps += d;
        std::hint::black_box(batched.grads(0)[0]);
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let grad_fp = grad_fingerprint(batched.grads(0));
    let state_memory_words = build_engine(case.engine, net, BENCH_N_OUT).state_memory_words();
    finish(
        case,
        net,
        &ops[0].since(&before),
        wall_ns,
        active_unit_steps,
        deriv_unit_steps,
        grad_fp,
        state_memory_words,
    )
}

/// Non-batchable engines at `batch > 1`: each lane steps sequentially
/// through one engine (no fusion to measure — this axis exists so every
/// engine still produces a width-B row for apples-to-apples throughput).
/// Lanes run in descending order so lane 0 finishes last and the engine's
/// gradient buffer holds lane 0's result — lane order is immaterial to the
/// numbers because `run_sequence` resets influence state per sequence.
fn run_case_serial_lanes(
    case: &BenchCase,
    net: &LayerStack,
    mut readout: Readout,
    mut loss: Loss,
) -> CaseResult {
    let b = case.batch;
    let mut engine = build_engine(case.engine, net, BENCH_N_OUT);
    engine.set_threads(case.threads);
    let inputs: Vec<Vec<Vec<f32>>> = (0..b).map(|s| lane_inputs(case, s)).collect();
    let targets = bench_targets(case.timesteps);

    let mut ops: Vec<OpCounter> = (0..b).map(|_| OpCounter::new()).collect();
    for _ in 0..case.warmup_sequences {
        for s in (0..b).rev() {
            engine.run_sequence(net, &mut readout, &mut loss, &inputs[s], &targets, &mut ops[s]);
        }
    }
    readout.zero_grads();

    let before = ops[0].clone();
    let mut active_unit_steps = 0usize;
    let mut deriv_unit_steps = 0usize;
    let t0 = Instant::now();
    for _ in 0..case.sequences {
        for s in (0..b).rev() {
            let summary =
                engine.run_sequence(net, &mut readout, &mut loss, &inputs[s], &targets, &mut ops[s]);
            if s == 0 {
                active_unit_steps += summary.active_unit_steps;
                deriv_unit_steps += summary.deriv_unit_steps;
            }
        }
        std::hint::black_box(engine.grads()[0]);
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let grad_fp = grad_fingerprint(engine.grads());
    finish(
        case,
        net,
        &ops[0].since(&before),
        wall_ns,
        active_unit_steps,
        deriv_unit_steps,
        grad_fp,
        engine.state_memory_words(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;

    fn case(engine: AlgorithmKind, omega: f32) -> BenchCase {
        BenchCase {
            engine,
            hidden: 8,
            layers: 1,
            param_sparsity: omega,
            timesteps: 6,
            sequences: 2,
            warmup_sequences: 1,
            theta: 0.1,
            threads: 1,
            batch: 1,
            seed: 7,
        }
    }

    /// The threads knob changes wall-clock only: per-phase and per-layer op
    /// counts are identical between a serial and a 2-worker run. At this
    /// tiny size the panels sit below the engine's parallel threshold, so
    /// this pins the *grid plumbing*; the threaded row update itself is
    /// exercised above-threshold by `tests/jacobian_slab.rs` and by the CI
    /// arm's `--hidden 64` invariance bench.
    #[test]
    fn intra_step_threads_do_not_change_op_counts() {
        for kind in [AlgorithmKind::RtrlBoth, AlgorithmKind::RtrlActivity] {
            let serial = run_case(&case(kind, 0.5));
            let mut c2 = case(kind, 0.5);
            c2.threads = 2;
            let threaded = run_case(&c2);
            assert_eq!(serial.macs_per_step, threaded.macs_per_step, "{kind:?}");
            assert_eq!(
                serial.macs_per_step_per_layer, threaded.macs_per_step_per_layer,
                "{kind:?}"
            );
            assert_eq!(serial.words_per_step_total, threaded.words_per_step_total);
            assert_eq!(serial.state_memory_words, threaded.state_memory_words);
            assert_eq!(serial.alpha_tilde.to_bits(), threaded.alpha_tilde.to_bits());
            assert_eq!(serial.beta_tilde.to_bits(), threaded.beta_tilde.to_bits());
        }
    }

    /// The tentpole acceptance invariant, locally: an `rtrl-param` case at
    /// batch widths 1 and 8 produces bit-identical lane-0 gradients (equal
    /// FNV fingerprints) and identical per-phase/per-layer op counts —
    /// structure built once per group is charged as if built per lane.
    #[test]
    fn batched_widths_share_gradient_fingerprint_and_ops() {
        let b1 = run_case(&case(AlgorithmKind::RtrlParam, 0.5));
        let mut c8 = case(AlgorithmKind::RtrlParam, 0.5);
        c8.batch = 8;
        let b8 = run_case(&c8);
        assert_eq!(b1.grad_fp, b8.grad_fp, "lane-0 gradient must be batch-invariant");
        assert_eq!(b1.macs_per_step, b8.macs_per_step);
        assert_eq!(b1.macs_per_step_per_layer, b8.macs_per_step_per_layer);
        assert_eq!(b1.words_per_step_total, b8.words_per_step_total);
        assert_eq!(b1.state_memory_words, b8.state_memory_words);
        assert_eq!(b1.alpha_tilde.to_bits(), b8.alpha_tilde.to_bits());
        assert_eq!(b1.beta_tilde.to_bits(), b8.beta_tilde.to_bits());
        assert_eq!((b1.batch, b8.batch), (1, 8));
    }

    /// Same invariant along the thread axis, under batching.
    #[test]
    fn batched_thread_counts_share_gradient_fingerprint() {
        let mut c = case(AlgorithmKind::RtrlParam, 0.5);
        c.batch = 4;
        let serial = run_case(&c);
        c.threads = 2;
        let threaded = run_case(&c);
        assert_eq!(serial.grad_fp, threaded.grad_fp);
        assert_eq!(serial.macs_per_step, threaded.macs_per_step);
        assert_eq!(serial.alpha_tilde.to_bits(), threaded.alpha_tilde.to_bits());
    }

    /// The serial-lane fallback reports lane 0 — so a non-batchable engine
    /// at width 3 fingerprints identically to its width-1 run, and its op
    /// counters stay per-lane.
    #[test]
    fn serial_lane_fallback_reports_lane_zero() {
        let b1 = run_case(&case(AlgorithmKind::RtrlBoth, 0.5));
        let mut c3 = case(AlgorithmKind::RtrlBoth, 0.5);
        c3.batch = 3;
        let b3 = run_case(&c3);
        assert_eq!(b1.grad_fp, b3.grad_fp, "lane 0 consumes the width-1 stream");
        assert_eq!(b1.macs_per_step, b3.macs_per_step);
        assert_eq!(b1.alpha_tilde.to_bits(), b3.alpha_tilde.to_bits());
        assert_eq!(b3.batch, 3);
        assert!(b3.wall_ns > 0);
    }

    #[test]
    fn deterministic_op_counts() {
        let a = run_case(&case(AlgorithmKind::RtrlBoth, 0.5));
        let b = run_case(&case(AlgorithmKind::RtrlBoth, 0.5));
        assert_eq!(a.macs_per_step, b.macs_per_step);
        assert_eq!(a.state_memory_words, b.state_memory_words);
        assert!((a.alpha_tilde - b.alpha_tilde).abs() < 1e-12);
    }

    #[test]
    fn every_engine_kind_measures() {
        for kind in AlgorithmKind::all() {
            let r = run_case(&case(kind, 0.5));
            assert_eq!(r.engine, kind.name());
            assert!(r.macs_per_step_total > 0, "{}: zero MACs", r.engine);
            assert!(r.wall_ns > 0);
        }
    }

    #[test]
    fn param_sparsity_reduces_tracked_columns_cost() {
        let dense = run_case(&case(AlgorithmKind::RtrlParam, 0.0));
        let sparse = run_case(&case(AlgorithmKind::RtrlParam, 0.8));
        assert!(
            sparse.macs_per_step_total < dense.macs_per_step_total,
            "ω=0.8 {} !< ω=0 {}",
            sparse.macs_per_step_total,
            dense.macs_per_step_total
        );
        assert!(sparse.omega_tilde < 0.5);
    }

    #[test]
    fn depth2_case_measures_every_engine() {
        for kind in AlgorithmKind::all() {
            let mut c = case(kind, 0.5);
            c.layers = 2;
            let r = run_case(&c);
            assert_eq!(r.layers, 2);
            assert_eq!(r.macs_per_step_per_layer.len(), 2);
            assert!(r.p > run_case(&case(kind, 0.5)).p, "depth should add params");
            assert!(r.macs_per_step_per_layer.iter().sum::<u64>() > 0);
        }
    }
}
