//! JSON emission **and parsing** for the bench report.
//!
//! In-tree because the build vendors no serde: the report schema is small,
//! append-only and versioned, so a hand-rolled writer with an escaping
//! helper plus a ~100-line recursive-descent value parser is the whole
//! requirement. The parser exists so the serialize→parse round-trip is
//! testable in-tree and so downstream perf-trajectory tooling has a
//! reference for dispatching on [`SCHEMA_VERSION`]: v1 reports (single-cell
//! era) carry no `layers` axis or per-layer counters; v2 adds depth; v3
//! adds the intra-step `threads` axis and throughput fields; v4 adds the
//! `snapshot_codecs` block (checkpoint encode/decode cost per format); v5
//! adds the `telemetry` block (observability overhead on the reference
//! session); v6 adds the shared-weight `batch` axis (`batch` + `grad_fp`
//! per case) and the `kernels` block (per-row-kernel ns/element); v7 adds
//! the `serve` block (multi-tenant serve-loop throughput and latency,
//! batched vs round-robin vs a resident budget).

use super::{phase_name, BenchReport, CaseResult};
use std::collections::BTreeMap;

/// Schema identifier CI consumers can dispatch on.
pub const SCHEMA: &str = "sparse-rtrl/bench/v7";
/// Monotone schema revision: bump on any breaking field change.
/// * 1 — single-cell grid (engine × hidden × ω).
/// * 2 — depth axis: `layers`, `macs_per_step_per_layer`,
///   `words_per_step_per_layer` per case; `schema_version` at the top.
/// * 3 — intra-step threads axis (`threads` at the top and per case) and
///   throughput fields (`seqs_per_sec` per case, alongside the existing
///   `steps_per_sec`). Op counts are thread-invariant by contract; CI
///   diffs a `--threads 1` vs `--threads 2` run on every PR.
/// * 4 — `snapshot_codecs` at the top: per-format checkpoint size and
///   encode/decode wall time on the reference session
///   ([`crate::bench::snapshot`]), so the binary-vs-JSON cost ratio is
///   part of the tracked perf trajectory.
/// * 5 — `telemetry` at the top: ns/step with telemetry off vs on, the
///   sampled α/β means and the step-latency summary on the reference
///   session ([`crate::bench::telemetry`]), so the cost of observability
///   is tracked like any other subsystem.
/// * 6 — the shared-weight batch axis: `batch` per case (lanes stepped
///   together; `rtrl-param` runs every width through the batched engine)
///   and `grad_fp` per case — lane 0's gradient fingerprint as a *decimal
///   string*, because this parser (like many) stores numbers as f64 and
///   would silently round a 64-bit integer. Also `kernels` at the top:
///   per-row-kernel ns/element at several densities
///   ([`crate::bench::kernels`]). CI diffs `grad_fp` and the op fields
///   across `--batch 1` vs `--batch 8` and `--threads 1` vs `--threads 2`.
/// * 7 — `serve` at the top: the multi-tenant serve-loop load test
///   ([`crate::bench::serve`]) — events/sec, p50/p99 lane-step latency and
///   residency churn per (schedule × tenant count × resident budget) over
///   one identical Zipf-skewed workload. CI gates the batched schedule at
///   ≥ 1.2× the round-robin baseline's events/sec on the quick grid.
pub const SCHEMA_VERSION: u64 = 7;

/// Escape a string for a JSON string literal (without the quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A float as a JSON number (`null` for non-finite values, which JSON
/// cannot represent).
pub fn number(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// f32 variant, formatted at f32 precision (so ω = 0.8 emits `0.8`, not
/// the f64-widened `0.800000011920929`).
pub fn number32(x: f32) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn u64_array(xs: &[u64]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

fn case_json(r: &CaseResult, indent: &str) -> String {
    let mut phases = String::new();
    for (i, macs) in r.macs_per_step.iter().enumerate() {
        if i > 0 {
            phases.push_str(", ");
        }
        phases.push_str(&format!("\"{}\": {}", escape(phase_name(i)), macs));
    }
    format!(
        "{indent}{{\"engine\": \"{}\", \"hidden\": {}, \"layers\": {}, \"param_sparsity\": {}, \
         \"omega_tilde\": {}, \"p\": {}, \"timesteps\": {}, \"sequences\": {}, \
         \"threads\": {}, \"batch\": {}, \"grad_fp\": \"{}\", \
         \"wall_ns\": {}, \"ns_per_step\": {}, \"steps_per_sec\": {}, \"seqs_per_sec\": {}, \
         \"macs_per_step_total\": {}, \"macs_per_step\": {{{}}}, \
         \"macs_per_step_per_layer\": {}, \"words_per_step_per_layer\": {}, \
         \"words_per_step_total\": {}, \"state_memory_words\": {}, \
         \"alpha_tilde\": {}, \"beta_tilde\": {}}}",
        escape(r.engine),
        r.hidden,
        r.layers,
        number32(r.param_sparsity),
        number32(r.omega_tilde),
        r.p,
        r.timesteps,
        r.sequences,
        r.threads,
        r.batch,
        r.grad_fp,
        r.wall_ns,
        number(r.ns_per_step),
        number(r.steps_per_sec),
        number(r.seqs_per_sec),
        r.macs_per_step_total,
        phases,
        u64_array(&r.macs_per_step_per_layer),
        u64_array(&r.words_per_step_per_layer),
        r.words_per_step_total,
        r.state_memory_words,
        number(r.alpha_tilde),
        number(r.beta_tilde),
    )
}

impl BenchReport {
    /// Serialize the whole report. One result object per line so diffs and
    /// line-oriented tooling stay usable on the CI artifact.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": \"{}\",\n", escape(SCHEMA)));
        s.push_str(&format!("  \"schema_version\": {SCHEMA_VERSION},\n"));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"timesteps\": {},\n", self.timesteps));
        s.push_str(&format!("  \"sequences\": {},\n", self.sequences));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"threads\": {},\n", self.threads));
        s.push_str(&format!("  \"created_unix\": {},\n", self.created_unix));
        s.push_str("  \"snapshot_codecs\": [\n");
        for (i, c) in self.snapshot_codecs.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"format\": \"{}\", \"bytes\": {}, \"encode_ns\": {}, \"decode_ns\": {}}}{}\n",
                escape(c.format),
                c.bytes,
                c.encode_ns,
                c.decode_ns,
                if i + 1 < self.snapshot_codecs.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        let t = &self.telemetry;
        s.push_str(&format!(
            "  \"telemetry\": {{\"steps\": {}, \"ns_per_step_off\": {}, \
             \"ns_per_step_on\": {}, \"points\": {}, \"alpha_mean\": {}, \"beta_mean\": {}, \
             \"latency_ns\": {{\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \
             \"p50\": {}, \"p99\": {}}}}},\n",
            t.steps,
            t.ns_per_step_off,
            t.ns_per_step_on,
            t.points,
            number32(t.alpha_mean),
            number32(t.beta_mean),
            t.latency_ns.count,
            t.latency_ns.sum,
            t.latency_ns.min,
            t.latency_ns.max,
            t.latency_ns.p50,
            t.latency_ns.p99,
        ));
        s.push_str("  \"kernels\": [\n");
        for (i, k) in self.kernels.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"kernel\": \"{}\", \"density\": {}, \"elements\": {}, \
                 \"ns_total\": {}, \"ns_per_element\": {}}}{}\n",
                escape(k.kernel),
                number32(k.density),
                k.elements,
                k.ns_total,
                number(k.ns_per_element),
                if i + 1 < self.kernels.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"serve\": [\n");
        for (i, r) in self.serve.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"schedule\": \"{}\", \"tenants\": {}, \"max_resident\": {}, \
                 \"threads\": {}, \"burst\": {}, \"events\": {}, \"rounds\": {}, \
                 \"wall_ns\": {}, \"events_per_sec\": {}, \"p50_step_ns\": {}, \
                 \"p99_step_ns\": {}, \"fused_lane_steps\": {}, \"solo_steps\": {}, \
                 \"evictions\": {}, \"admissions\": {}}}{}\n",
                escape(r.schedule),
                r.tenants,
                r.max_resident,
                r.threads,
                r.burst,
                r.events,
                r.rounds,
                r.wall_ns,
                number(r.events_per_sec),
                r.p50_step_ns,
                r.p99_step_ns,
                r.fused_lane_steps,
                r.solo_steps,
                r.evictions,
                r.admissions,
                if i + 1 < self.serve.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            s.push_str(&case_json(r, "    "));
            if i + 1 < self.results.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }
}

// ---------------------------------------------------------------------
// Parsing (reference consumer + round-trip tests)
// ---------------------------------------------------------------------

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse a JSON document (strict enough for the bench schema: objects,
/// arrays, strings with the escapes [`escape`] emits, numbers, booleans,
/// null). Returns a byte-offset-annotated error on malformed input.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing content at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    other => return Err(format!("object key must be a string, got {other:?}")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(arr));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'"') => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => return Err("unterminated string".into()),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    Some(b'\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'n') => s.push('\n'),
                            Some(b'r') => s.push('\r'),
                            Some(b't') => s.push('\t'),
                            Some(b'u') => {
                                let hex = b
                                    .get(*pos + 1..*pos + 5)
                                    .ok_or("truncated \\u escape")?;
                                let code = u32::from_str_radix(
                                    std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                    16,
                                )
                                .map_err(|_| "bad \\u escape")?;
                                s.push(char::from_u32(code).ok_or("invalid \\u codepoint")?);
                                *pos += 4;
                            }
                            other => return Err(format!("bad escape {other:?}")),
                        }
                        *pos += 1;
                    }
                    Some(_) => {
                        // advance over one UTF-8 scalar
                        let start = *pos;
                        let mut end = start + 1;
                        while end < b.len() && (b[end] & 0xC0) == 0x80 {
                            end += 1;
                        }
                        s.push_str(
                            std::str::from_utf8(&b[start..end]).map_err(|_| "invalid UTF-8")?,
                        );
                        *pos = end;
                    }
                }
            }
        }
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && !matches!(b[*pos], b',' | b'}' | b']' | b' ' | b'\t' | b'\n' | b'\r')
            {
                *pos += 1;
            }
            let tok = std::str::from_utf8(&b[start..*pos]).map_err(|_| "invalid UTF-8")?;
            match tok {
                "null" => Ok(Json::Null),
                "true" => Ok(Json::Bool(true)),
                "false" => Ok(Json::Bool(false)),
                _ => tok
                    .parse::<f64>()
                    .map(Json::Num)
                    .map_err(|_| format!("cannot parse token {tok:?} at byte {start}")),
            }
        }
    }
}

/// Reference consumer: detect the schema revision of a serialized report.
/// v1 reports predate `schema_version`, so its absence means 1.
pub fn schema_version_of(doc: &Json) -> u64 {
    doc.get("schema_version").and_then(Json::as_u64).unwrap_or(1)
}

/// Reference consumer: check a parsed report is a complete current-version
/// document. Section presence is checked **before** the version gate, so a
/// stale file fails with the *name of the missing section* — a v4 report
/// is rejected as `bench report section "telemetry": missing (…)`, which
/// tells the consumer exactly what its file predates, not just that some
/// number is wrong.
pub fn validate(doc: &Json) -> Result<(), String> {
    for (key, since) in [
        ("schema", "v1"),
        ("results", "v1"),
        ("schema_version", "v2"),
        ("threads", "v3"),
        ("snapshot_codecs", "v4"),
        ("telemetry", "v5"),
        ("kernels", "v6"),
        ("serve", "v7"),
    ] {
        if doc.get(key).is_none() {
            return Err(format!("bench report section {key:?}: missing (added in {since})"));
        }
    }
    let version = schema_version_of(doc);
    if version != SCHEMA_VERSION {
        return Err(format!(
            "bench schema_version {version} unsupported (this build writes {SCHEMA_VERSION})"
        ));
    }
    let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("");
    if schema != SCHEMA {
        return Err(format!("unknown bench schema {schema:?} (expected {SCHEMA:?})"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::{run, BenchConfig};
    use crate::config::AlgorithmKind;

    fn tiny_report() -> BenchReport {
        let cfg = BenchConfig {
            engines: vec![AlgorithmKind::RtrlDense, AlgorithmKind::Uoro],
            hidden_sizes: vec![6],
            layers: vec![1, 2],
            param_sparsities: vec![0.0],
            timesteps: 4,
            sequences: 1,
            warmup_sequences: 0,
            theta: 0.1,
            workers: 1,
            threads: 1,
            batches: vec![1],
            serve_tenants: vec![2],
            serve_events: 12,
            serve_threads: 1,
            quick: true,
        };
        run(&cfg, false)
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn number_maps_non_finite_to_null() {
        assert_eq!(number(1.5), "1.5");
        assert_eq!(number(f64::NAN), "null");
        assert_eq!(number(f64::INFINITY), "null");
    }

    #[test]
    fn parser_handles_scalars_arrays_objects() {
        let doc = parse(r#"{"a": [1, 2.5, null, true], "s": "x\ny", "o": {"k": -3}}"#).unwrap();
        assert_eq!(doc.get("a").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(doc.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(doc.get("s").unwrap().as_str(), Some("x\ny"));
        assert_eq!(doc.get("o").unwrap().get("k").unwrap().as_f64(), Some(-3.0));
        assert!(parse("{\"unterminated\": ").is_err());
        assert!(parse("{} trailing").is_err());
    }

    /// Serialize → parse round-trip: every load-bearing field of the v3
    /// schema survives — the depth axis, the threads axis, the throughput
    /// fields — and the version is detectable. This is the contract
    /// downstream perf tooling relies on to tell v3 reports from older
    /// files instead of misreading them.
    #[test]
    fn report_round_trips_through_parser() {
        let report = tiny_report();
        let doc = parse(&report.to_json()).expect("report must parse");
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(schema_version_of(&doc), SCHEMA_VERSION);
        assert_eq!(doc.get("timesteps").unwrap().as_u64(), Some(report.timesteps as u64));
        assert_eq!(doc.get("threads").unwrap().as_u64(), Some(report.threads as u64));
        // v4: the snapshot-codec block survives the round trip
        let codecs = doc.get("snapshot_codecs").unwrap().as_arr().unwrap();
        assert_eq!(codecs.len(), report.snapshot_codecs.len());
        for (parsed, orig) in codecs.iter().zip(&report.snapshot_codecs) {
            assert_eq!(parsed.get("format").unwrap().as_str(), Some(orig.format));
            assert_eq!(parsed.get("bytes").unwrap().as_u64(), Some(orig.bytes as u64));
            assert_eq!(parsed.get("encode_ns").unwrap().as_u64(), Some(orig.encode_ns));
            assert_eq!(parsed.get("decode_ns").unwrap().as_u64(), Some(orig.decode_ns));
        }
        // v5: the telemetry block survives the round trip
        let tel = doc.get("telemetry").unwrap();
        assert_eq!(tel.get("steps").unwrap().as_u64(), Some(report.telemetry.steps));
        assert_eq!(
            tel.get("ns_per_step_off").unwrap().as_u64(),
            Some(report.telemetry.ns_per_step_off)
        );
        assert_eq!(
            tel.get("ns_per_step_on").unwrap().as_u64(),
            Some(report.telemetry.ns_per_step_on)
        );
        assert_eq!(tel.get("points").unwrap().as_u64(), Some(report.telemetry.points));
        let lat = tel.get("latency_ns").unwrap();
        assert_eq!(lat.get("count").unwrap().as_u64(), Some(report.telemetry.latency_ns.count));
        assert_eq!(lat.get("p99").unwrap().as_u64(), Some(report.telemetry.latency_ns.p99));
        // v6: the kernel micro-bench block survives the round trip
        let kernels = doc.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels.len(), report.kernels.len());
        assert!(!kernels.is_empty());
        for (parsed, orig) in kernels.iter().zip(&report.kernels) {
            assert_eq!(parsed.get("kernel").unwrap().as_str(), Some(orig.kernel));
            assert_eq!(parsed.get("elements").unwrap().as_u64(), Some(orig.elements));
            assert_eq!(parsed.get("ns_total").unwrap().as_u64(), Some(orig.ns_total));
            assert!(parsed.get("ns_per_element").unwrap().as_f64().is_some());
        }
        // v7: the serve block survives the round trip
        let serve = doc.get("serve").unwrap().as_arr().unwrap();
        assert_eq!(serve.len(), report.serve.len());
        assert!(!serve.is_empty());
        for (parsed, orig) in serve.iter().zip(&report.serve) {
            assert_eq!(parsed.get("schedule").unwrap().as_str(), Some(orig.schedule));
            assert_eq!(parsed.get("tenants").unwrap().as_u64(), Some(orig.tenants as u64));
            assert_eq!(
                parsed.get("max_resident").unwrap().as_u64(),
                Some(orig.max_resident as u64)
            );
            assert_eq!(parsed.get("events").unwrap().as_u64(), Some(orig.events));
            assert_eq!(
                parsed.get("fused_lane_steps").unwrap().as_u64(),
                Some(orig.fused_lane_steps)
            );
            assert_eq!(parsed.get("solo_steps").unwrap().as_u64(), Some(orig.solo_steps));
            assert!(parsed.get("events_per_sec").unwrap().as_f64().is_some());
            assert_eq!(parsed.get("p99_step_ns").unwrap().as_u64(), Some(orig.p99_step_ns));
        }
        validate(&doc).expect("freshly written report must validate");
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), report.results.len());
        for (parsed, orig) in results.iter().zip(&report.results) {
            assert_eq!(parsed.get("engine").unwrap().as_str(), Some(orig.engine));
            assert_eq!(parsed.get("hidden").unwrap().as_u64(), Some(orig.hidden as u64));
            assert_eq!(parsed.get("layers").unwrap().as_u64(), Some(orig.layers as u64));
            assert_eq!(parsed.get("threads").unwrap().as_u64(), Some(orig.threads as u64));
            // v6: batch width is a number; the 64-bit gradient fingerprint
            // rides as a decimal string so the f64-backed parser keeps
            // every bit
            assert_eq!(parsed.get("batch").unwrap().as_u64(), Some(orig.batch as u64));
            let fp: u64 = parsed
                .get("grad_fp")
                .unwrap()
                .as_str()
                .expect("grad_fp must be a string")
                .parse()
                .expect("grad_fp must be a decimal u64");
            assert_eq!(fp, orig.grad_fp);
            let sps = parsed.get("seqs_per_sec").unwrap().as_f64().unwrap();
            assert!((sps - orig.seqs_per_sec).abs() < 1e-6 * (1.0 + sps.abs()));
            assert_eq!(
                parsed.get("macs_per_step_total").unwrap().as_u64(),
                Some(orig.macs_per_step_total)
            );
            let per_layer: Vec<u64> = parsed
                .get("macs_per_step_per_layer")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_u64().unwrap())
                .collect();
            assert_eq!(per_layer, orig.macs_per_step_per_layer);
            let words_per_layer = parsed.get("words_per_step_per_layer").unwrap().as_arr().unwrap();
            assert_eq!(words_per_layer.len(), orig.layers);
            // per-phase map survives
            assert!(parsed.get("macs_per_step").unwrap().get("influence_update").is_some());
        }
        // the depth axis genuinely varies in the grid
        let depths: Vec<u64> =
            results.iter().map(|r| r.get("layers").unwrap().as_u64().unwrap()).collect();
        assert!(depths.contains(&1) && depths.contains(&2));
    }

    /// A v1-era document (no `schema_version`) is detected as version 1.
    #[test]
    fn v1_documents_detected_as_version_1() {
        let doc = parse(r#"{"schema": "sparse-rtrl/bench/v1", "results": []}"#).unwrap();
        assert_eq!(schema_version_of(&doc), 1);
    }

    /// The stale-report satellite: a v4 document — structurally complete
    /// for its era but predating the telemetry block — must be rejected
    /// with an error that *names the missing section*, not a bare version
    /// mismatch. The section check runs before the version gate precisely
    /// so the message says what the file lacks.
    #[test]
    fn v4_report_rejected_by_missing_telemetry_section() {
        let v4 = r#"{
            "schema": "sparse-rtrl/bench/v4",
            "schema_version": 4,
            "threads": 1,
            "snapshot_codecs": [],
            "results": []
        }"#;
        let doc = parse(v4).unwrap();
        assert_eq!(schema_version_of(&doc), 4);
        let err = validate(&doc).unwrap_err();
        assert!(err.contains("\"telemetry\""), "error must name the section: {err}");
        assert!(err.contains("missing"), "error must say it is missing: {err}");
        assert!(err.contains("v5"), "error must say which revision added it: {err}");
    }

    /// A v5 document — complete for its era but predating the batch axis
    /// and the kernel micro-bench — is rejected with the name of the
    /// section it lacks, same contract as the v4 case above.
    #[test]
    fn v5_report_rejected_by_missing_kernels_section() {
        let v5 = r#"{
            "schema": "sparse-rtrl/bench/v5",
            "schema_version": 5,
            "threads": 1,
            "snapshot_codecs": [],
            "telemetry": {},
            "results": []
        }"#;
        let doc = parse(v5).unwrap();
        assert_eq!(schema_version_of(&doc), 5);
        let err = validate(&doc).unwrap_err();
        assert!(err.contains("\"kernels\""), "error must name the section: {err}");
        assert!(err.contains("missing"), "error must say it is missing: {err}");
        assert!(err.contains("v6"), "error must say which revision added it: {err}");
    }

    /// A v6 document — complete for its era but predating the serve block —
    /// is rejected with the name of the section it lacks, same contract as
    /// the v4/v5 cases above.
    #[test]
    fn v6_report_rejected_by_missing_serve_section() {
        let v6 = r#"{
            "schema": "sparse-rtrl/bench/v6",
            "schema_version": 6,
            "threads": 1,
            "snapshot_codecs": [],
            "telemetry": {},
            "kernels": [],
            "results": []
        }"#;
        let doc = parse(v6).unwrap();
        assert_eq!(schema_version_of(&doc), 6);
        let err = validate(&doc).unwrap_err();
        assert!(err.contains("\"serve\""), "error must name the section: {err}");
        assert!(err.contains("missing"), "error must say it is missing: {err}");
        assert!(err.contains("v7"), "error must say which revision added it: {err}");
    }

    /// Version and schema-string gates still fire once all sections exist.
    #[test]
    fn validate_gates_version_and_schema_string() {
        let stale_version = parse(
            r#"{"schema": "sparse-rtrl/bench/v7", "schema_version": 6,
                "threads": 1, "snapshot_codecs": [], "telemetry": {}, "kernels": [],
                "serve": [], "results": []}"#,
        )
        .unwrap();
        let err = validate(&stale_version).unwrap_err();
        assert!(err.contains("schema_version 6"), "{err}");

        let wrong_schema = parse(
            r#"{"schema": "someone-else/bench/v7", "schema_version": 7,
                "threads": 1, "snapshot_codecs": [], "telemetry": {}, "kernels": [],
                "serve": [], "results": []}"#,
        )
        .unwrap();
        let err = validate(&wrong_schema).unwrap_err();
        assert!(err.contains("unknown bench schema"), "{err}");
    }

    /// Structural validation with an in-test micro JSON checker: balanced
    /// braces/brackets outside strings, expected keys present.
    #[test]
    fn report_json_is_balanced_and_complete() {
        let j = tiny_report().to_json();
        let (mut depth, mut in_str, mut esc_next) = (0i32, false, false);
        let mut max_depth = 0;
        for c in j.chars() {
            if esc_next {
                esc_next = false;
                continue;
            }
            match c {
                '\\' if in_str => esc_next = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => {
                    depth += 1;
                    max_depth = max_depth.max(depth);
                }
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        assert_eq!(depth, 0, "unbalanced JSON:\n{j}");
        assert!(!in_str, "unterminated string");
        assert!(max_depth >= 3, "results objects missing");
        for key in [
            "\"schema\"",
            "\"schema_version\"",
            "\"snapshot_codecs\"",
            "\"encode_ns\"",
            "\"decode_ns\"",
            "\"telemetry\"",
            "\"ns_per_step_off\"",
            "\"ns_per_step_on\"",
            "\"latency_ns\"",
            "\"kernels\"",
            "\"ns_per_element\"",
            "\"serve\"",
            "\"events_per_sec\"",
            "\"fused_lane_steps\"",
            "\"max_resident\"",
            "\"results\"",
            "\"engine\"",
            "\"layers\"",
            "\"threads\"",
            "\"batch\"",
            "\"grad_fp\"",
            "\"ns_per_step\"",
            "\"steps_per_sec\"",
            "\"seqs_per_sec\"",
            "\"macs_per_step\"",
            "\"macs_per_step_per_layer\"",
            "\"influence_update\"",
            "\"state_memory_words\"",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        assert!(j.contains(SCHEMA));
        assert!(j.contains("\"rtrl-dense\""));
        assert!(j.contains("\"uoro\""));
    }
}
