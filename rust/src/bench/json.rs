//! Minimal JSON emission for the bench report.
//!
//! In-tree because the build vendors no serde: the report schema is small,
//! append-only and versioned, so a hand-rolled writer with an escaping
//! helper is the whole requirement. The inverse direction (parsing) is
//! deliberately out of scope — CI consumers read the artifact with real
//! JSON tooling.

use super::{phase_name, BenchReport, CaseResult};

/// Schema identifier CI consumers can dispatch on.
pub const SCHEMA: &str = "sparse-rtrl/bench/v1";

/// Escape a string for a JSON string literal (without the quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A float as a JSON number (`null` for non-finite values, which JSON
/// cannot represent).
pub fn number(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// f32 variant, formatted at f32 precision (so ω = 0.8 emits `0.8`, not
/// the f64-widened `0.800000011920929`).
pub fn number32(x: f32) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn case_json(r: &CaseResult, indent: &str) -> String {
    let mut phases = String::new();
    for (i, macs) in r.macs_per_step.iter().enumerate() {
        if i > 0 {
            phases.push_str(", ");
        }
        phases.push_str(&format!("\"{}\": {}", escape(phase_name(i)), macs));
    }
    format!(
        "{indent}{{\"engine\": \"{}\", \"hidden\": {}, \"param_sparsity\": {}, \
         \"omega_tilde\": {}, \"p\": {}, \"timesteps\": {}, \"sequences\": {}, \
         \"wall_ns\": {}, \"ns_per_step\": {}, \"steps_per_sec\": {}, \
         \"macs_per_step_total\": {}, \"macs_per_step\": {{{}}}, \
         \"words_per_step_total\": {}, \"state_memory_words\": {}, \
         \"alpha_tilde\": {}, \"beta_tilde\": {}}}",
        escape(r.engine),
        r.hidden,
        number32(r.param_sparsity),
        number32(r.omega_tilde),
        r.p,
        r.timesteps,
        r.sequences,
        r.wall_ns,
        number(r.ns_per_step),
        number(r.steps_per_sec),
        r.macs_per_step_total,
        phases,
        r.words_per_step_total,
        r.state_memory_words,
        number(r.alpha_tilde),
        number(r.beta_tilde),
    )
}

impl BenchReport {
    /// Serialize the whole report. One result object per line so diffs and
    /// line-oriented tooling stay usable on the CI artifact.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": \"{}\",\n", escape(SCHEMA)));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"timesteps\": {},\n", self.timesteps));
        s.push_str(&format!("  \"sequences\": {},\n", self.sequences));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"created_unix\": {},\n", self.created_unix));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            s.push_str(&case_json(r, "    "));
            if i + 1 < self.results.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::{run, BenchConfig};
    use crate::config::AlgorithmKind;

    fn tiny_report() -> BenchReport {
        let cfg = BenchConfig {
            engines: vec![AlgorithmKind::RtrlDense, AlgorithmKind::Uoro],
            hidden_sizes: vec![6],
            param_sparsities: vec![0.0],
            timesteps: 4,
            sequences: 1,
            warmup_sequences: 0,
            theta: 0.1,
            workers: 1,
            quick: true,
        };
        run(&cfg, false)
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn number_maps_non_finite_to_null() {
        assert_eq!(number(1.5), "1.5");
        assert_eq!(number(f64::NAN), "null");
        assert_eq!(number(f64::INFINITY), "null");
    }

    /// Structural validation with an in-test micro JSON checker: balanced
    /// braces/brackets outside strings, expected keys present.
    #[test]
    fn report_json_is_balanced_and_complete() {
        let j = tiny_report().to_json();
        let (mut depth, mut in_str, mut esc_next) = (0i32, false, false);
        let mut max_depth = 0;
        for c in j.chars() {
            if esc_next {
                esc_next = false;
                continue;
            }
            match c {
                '\\' if in_str => esc_next = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => {
                    depth += 1;
                    max_depth = max_depth.max(depth);
                }
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        assert_eq!(depth, 0, "unbalanced JSON:\n{j}");
        assert!(!in_str, "unterminated string");
        assert!(max_depth >= 3, "results objects missing");
        for key in [
            "\"schema\"",
            "\"results\"",
            "\"engine\"",
            "\"ns_per_step\"",
            "\"macs_per_step\"",
            "\"influence_update\"",
            "\"state_memory_words\"",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        assert!(j.contains(SCHEMA));
        assert!(j.contains("\"rtrl-dense\""));
        assert!(j.contains("\"uoro\""));
    }
}
