//! Snapshot-codec measurements for the bench report: encode/decode wall
//! time and byte size per [`SnapshotFormat`], on one representative driven
//! session.
//!
//! The numbers answer the eviction-loop question — how much does spilling
//! a session cost, per format? — and land in `BENCH_rtrl.json`
//! (`snapshot_codecs`, schema v4) so the codec's perf trajectory is
//! tracked alongside the engines'. The binary container is required to be
//! several times smaller and faster than the JSON interchange; CI reads
//! these fields to hold that line.

use crate::config::AlgorithmKind;
use crate::rtrl::Target;
use crate::session::codec::{codec_for, SnapshotFormat};
use crate::session::{SessionBuilder, SessionCheckpoint, UpdatePolicy};
use crate::util::Pcg64;

/// Encode/decode cost of one snapshot format on the reference checkpoint.
#[derive(Debug, Clone)]
pub struct SnapshotCodecResult {
    /// Format name ([`SnapshotFormat::name`]).
    pub format: &'static str,
    /// Serialized snapshot size in bytes.
    pub bytes: usize,
    /// Best-of-reps wall time to encode the checkpoint, nanoseconds.
    pub encode_ns: u64,
    /// Best-of-reps wall time to decode it back, nanoseconds.
    pub decode_ns: u64,
}

/// The reference checkpoint: a mid-stream sparse session at bench-like
/// scale (n = 32, ω = 0.8, the paper's combined-sparsity engine), driven
/// long enough that every field group — params, Adam moments, masks,
/// influence state — is populated and non-trivial.
fn reference_checkpoint() -> SessionCheckpoint {
    let mut s = SessionBuilder::new()
        .algorithm(AlgorithmKind::RtrlBoth)
        .hidden(32)
        .param_sparsity(0.8)
        .policy(UpdatePolicy::EveryKSteps(2))
        .build();
    let mut rng = Pcg64::new(17);
    for i in 0..24 {
        let x = [rng.normal(), rng.normal()];
        let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
        s.step(&x, t);
    }
    s.checkpoint()
}

fn best_of<F: FnMut()>(reps: usize, mut f: F) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        f();
        best = best.min(t0.elapsed().as_nanos() as u64);
    }
    best
}

/// Measure every snapshot format on the reference checkpoint. `reps` is
/// the best-of repetition count (timing noise control; sizes are exact).
pub fn measure(reps: usize) -> Vec<SnapshotCodecResult> {
    let ck = reference_checkpoint();
    SnapshotFormat::all()
        .into_iter()
        .map(|format| {
            let codec = codec_for(format);
            let bytes = codec.encode(&ck);
            let encode_ns = best_of(reps, || {
                std::hint::black_box(codec.encode(std::hint::black_box(&ck)));
            });
            let decode_ns = best_of(reps, || {
                std::hint::black_box(codec.decode(std::hint::black_box(&bytes)).unwrap());
            });
            SnapshotCodecResult { format: format.name(), bytes: bytes.len(), encode_ns, decode_ns }
        })
        .collect()
}

/// The rep count the bench run uses.
pub const DEFAULT_REPS: usize = 7;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_every_format_with_nonzero_cost() {
        let results = measure(2);
        assert_eq!(results.len(), SnapshotFormat::all().len());
        for r in &results {
            assert!(r.bytes > 0, "{}: empty snapshot", r.format);
            assert!(r.encode_ns > 0 && r.decode_ns > 0, "{}: no time measured", r.format);
        }
    }

    /// The size claim is deterministic: the binary container is ≥ 3×
    /// smaller than the JSON interchange on the reference checkpoint.
    /// (The speed claim — binary several times faster — is recorded in the
    /// report and enforced by CI on real hardware, not asserted here where
    /// test parallelism makes wall time noisy.)
    #[test]
    fn binary_is_at_least_3x_smaller() {
        let results = measure(1);
        let by_name = |n: &str| results.iter().find(|r| r.format == n).unwrap();
        let (bin, json) = (by_name("binary"), by_name("json"));
        assert!(
            bin.bytes * 3 <= json.bytes,
            "binary {} B not 3× smaller than json {} B",
            bin.bytes,
            json.bytes
        );
    }
}
