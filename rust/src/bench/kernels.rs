//! Row-kernel micro-bench: each kernel from [`crate::rtrl::kernels`] timed
//! in isolation, at several row densities, in ns per processed element.
//!
//! The engine-level bench cases measure kernels only in aggregate — a
//! regression in one kernel's inner loop hides inside a whole step. This
//! module pins each kernel alone on synthetic rows shaped like the real
//! influence panels (contiguous `pc`-wide rows, `u32` column lists,
//! lane-interleaved panels for the batched variants), so the per-kernel
//! cost lands in the bench report (`kernels` block, schema v6) and CI
//! tracks it like any other perf surface.
//!
//! Density here means the fraction of *structural* work per row: the
//! fraction of source rows a gather consumes, of columns a scatter or
//! sparse dot touches. Dense kernels (`axpy`, `scale_flush`, their panel
//! forms, `dot_dense_acc`) do width-proportional work regardless, so they
//! are measured at density 1.0 only.

use crate::rtrl::kernels::{
    axpy, axpy_panel, dot_dense_acc, dot_sparse_acc, fused_gather, gather_panel, scale_flush,
    scale_flush_panel, scatter_axpy,
};
use crate::util::Pcg64;
use std::hint::black_box;
use std::time::Instant;

/// Timed repetitions (per kernel × density) for the default bench run —
/// enough to smooth scheduler noise without dominating the smoke bench.
pub const DEFAULT_REPS: usize = 7;

/// Row width `pc` of the synthetic panel (columns per influence row).
const ROW_W: usize = 512;
/// Gatherable source rows / scatterable columns behind each call.
const SRC_ROWS: usize = 96;
/// Lane width of the panel-kernel variants (the batched stepping shape).
const PANEL_LANES: usize = 8;
/// Kernel invocations per timed repetition.
const CALLS: usize = 64;

/// Structural densities the sparse kernels are measured at.
const DENSITIES: [f32; 4] = [1.0, 0.5, 0.2, 0.05];

/// One (kernel, density) micro-measurement.
#[derive(Debug, Clone)]
pub struct KernelBenchResult {
    /// Kernel name as exported by [`crate::rtrl::kernels`].
    pub kernel: &'static str,
    /// Structural density of the synthetic rows (1.0 = dense).
    pub density: f32,
    /// Elements processed across all timed calls.
    pub elements: u64,
    /// Total timed wall-clock nanoseconds.
    pub ns_total: u64,
    pub ns_per_element: f64,
}

/// Deterministic synthetic state shared by every kernel measurement.
struct Fixture {
    /// `SRC_ROWS` contiguous `ROW_W`-wide source rows.
    src: Vec<f32>,
    /// Lane-interleaved panel sources, `ROW_W * PANEL_LANES` wide.
    src_panel: Vec<f32>,
    dst: Vec<f32>,
    dst_panel: Vec<f32>,
}

impl Fixture {
    fn new() -> Self {
        let mut rng = Pcg64::new(0xbe2c_f00d);
        let mut fill = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.normal()).collect() };
        Fixture {
            src: fill(SRC_ROWS * ROW_W),
            src_panel: fill(SRC_ROWS * ROW_W * PANEL_LANES),
            dst: fill(ROW_W),
            dst_panel: fill(ROW_W * PANEL_LANES),
        }
    }

    fn src_row(&self, r: usize) -> &[f32] {
        &self.src[r * ROW_W..(r + 1) * ROW_W]
    }
}

/// Evenly spread structural work: `⌈density · total⌉` indices out of
/// `0..total`, ascending — the shape the slab builder produces.
fn pick(total: usize, density: f32) -> Vec<u32> {
    let count = ((total as f32 * density).ceil() as usize).clamp(1, total);
    (0..count).map(|i| (i * total / count) as u32).collect()
}

fn time_calls(mut f: impl FnMut(), reps: usize) -> u64 {
    f(); // warm the caches untimed
    let t0 = Instant::now();
    for _ in 0..reps {
        for _ in 0..CALLS {
            f();
        }
    }
    t0.elapsed().as_nanos() as u64
}

fn result(kernel: &'static str, density: f32, per_call: u64, ns: u64, reps: usize) -> KernelBenchResult {
    let elements = per_call * (reps * CALLS) as u64;
    KernelBenchResult {
        kernel,
        density,
        elements,
        ns_total: ns,
        ns_per_element: if elements > 0 { ns as f64 / elements as f64 } else { 0.0 },
    }
}

/// Measure every row kernel at every applicable density. Deterministic
/// inputs (fixed PCG seed); wall time obviously varies with the host.
pub fn measure(reps: usize) -> Vec<KernelBenchResult> {
    let reps = reps.max(1);
    let mut fx = Fixture::new();
    let mut out = Vec::new();

    for &density in &DENSITIES {
        // fused_gather: density controls how many source rows contribute
        let rows = pick(SRC_ROWS, density);
        let jlist: Vec<(u32, f32)> =
            rows.iter().enumerate().map(|(i, &r)| (r, 0.3 + 0.01 * i as f32)).collect();
        let mut dst = fx.dst.clone();
        let src = &fx.src;
        let ns = time_calls(
            || {
                fused_gather(&mut dst, &jlist, |r| &src[r * ROW_W..(r + 1) * ROW_W]);
                black_box(dst[0]);
            },
            reps,
        );
        out.push(result("fused_gather", density, (jlist.len() * ROW_W) as u64, ns, reps));

        // gather_panel: same structure, PANEL_LANES lanes wide
        let vals: Vec<f32> = (0..rows.len() * PANEL_LANES).map(|i| 0.2 + 0.001 * i as f32).collect();
        let mut dstp = fx.dst_panel.clone();
        let srcp = &fx.src_panel;
        let w = ROW_W * PANEL_LANES;
        let ns = time_calls(
            || {
                gather_panel(&mut dstp, &rows, &vals, |r| &srcp[r * w..(r + 1) * w], PANEL_LANES);
                black_box(dstp[0]);
            },
            reps,
        );
        out.push(result("gather_panel", density, (rows.len() * w) as u64, ns, reps));

        // scatter_axpy / dot_sparse_acc: density controls touched columns
        let cols = pick(ROW_W, density);
        let svals: Vec<f32> = (0..cols.len()).map(|i| 0.1 + 0.002 * i as f32).collect();
        let mut dst = fx.dst.clone();
        let ns = time_calls(
            || {
                scatter_axpy(&mut dst, 0.99, &cols, &svals);
                black_box(dst[0]);
            },
            reps,
        );
        out.push(result("scatter_axpy", density, cols.len() as u64, ns, reps));

        let x = fx.src_row(0);
        let ns = time_calls(
            || {
                black_box(dot_sparse_acc(0.0, &cols, &svals, x));
            },
            reps,
        );
        out.push(result("dot_sparse_acc", density, cols.len() as u64, ns, reps));
    }

    // dense kernels: width-proportional work, one density point each
    let src_row0: Vec<f32> = fx.src_row(0).to_vec();
    let mut dst = fx.dst.clone();
    let ns = time_calls(
        || {
            axpy(&mut dst, 0.999, &src_row0);
            black_box(dst[0]);
        },
        reps,
    );
    out.push(result("axpy", 1.0, ROW_W as u64, ns, reps));

    let coef: Vec<f32> = (0..PANEL_LANES).map(|s| 0.99 + 0.001 * s as f32).collect();
    let srcp_row: Vec<f32> = fx.src_panel[..ROW_W * PANEL_LANES].to_vec();
    let mut dstp = fx.dst_panel.clone();
    let ns = time_calls(
        || {
            axpy_panel(&mut dstp, &coef, &srcp_row, PANEL_LANES);
            black_box(dstp[0]);
        },
        reps,
    );
    out.push(result("axpy_panel", 1.0, (ROW_W * PANEL_LANES) as u64, ns, reps));

    // gains ~1 so repeated in-place rescaling neither over- nor underflows
    let ns = time_calls(
        || {
            scale_flush(&mut fx.dst, 1.0001);
            black_box(fx.dst[0]);
        },
        reps,
    );
    out.push(result("scale_flush", 1.0, ROW_W as u64, ns, reps));

    let gains: Vec<f32> = (0..PANEL_LANES).map(|s| 1.0001 - 0.0002 * s as f32).collect();
    let ns = time_calls(
        || {
            scale_flush_panel(&mut fx.dst_panel, &gains, PANEL_LANES);
            black_box(fx.dst_panel[0]);
        },
        reps,
    );
    out.push(result("scale_flush_panel", 1.0, (ROW_W * PANEL_LANES) as u64, ns, reps));

    let vals: Vec<f32> = (0..ROW_W).map(|i| 0.1 + 0.001 * i as f32).collect();
    let x2: Vec<f32> = fx.src_row(1).to_vec();
    let ns = time_calls(
        || {
            black_box(dot_dense_acc(0.0, &vals, &x2));
        },
        reps,
    );
    out.push(result("dot_dense_acc", 1.0, ROW_W as u64, ns, reps));

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_every_kernel_at_every_applicable_density() {
        let rs = measure(1);
        let sparse = ["fused_gather", "gather_panel", "scatter_axpy", "dot_sparse_acc"];
        for k in sparse {
            let ds: Vec<f32> =
                rs.iter().filter(|r| r.kernel == k).map(|r| r.density).collect();
            assert_eq!(ds, DENSITIES.to_vec(), "{k} must cover every density");
        }
        for k in ["axpy", "axpy_panel", "scale_flush", "scale_flush_panel", "dot_dense_acc"] {
            assert_eq!(rs.iter().filter(|r| r.kernel == k).count(), 1, "{k} once, dense");
        }
        for r in &rs {
            assert!(r.elements > 0, "{}: no elements", r.kernel);
            assert!(r.ns_per_element.is_finite() && r.ns_per_element >= 0.0);
            assert_eq!(
                r.ns_per_element,
                r.ns_total as f64 / r.elements as f64,
                "{}: derived field must agree",
                r.kernel
            );
        }
    }

    #[test]
    fn density_scales_structural_work() {
        let rs = measure(1);
        let at = |k: &str, d: f32| {
            rs.iter().find(|r| r.kernel == k && r.density == d).unwrap().elements
        };
        for k in ["fused_gather", "scatter_axpy", "dot_sparse_acc"] {
            assert!(at(k, 0.05) < at(k, 1.0), "{k}: density must shrink the work");
        }
    }

    #[test]
    fn pick_spreads_and_clamps() {
        assert_eq!(pick(10, 1.0).len(), 10);
        assert_eq!(pick(10, 0.001).len(), 1, "at least one index survives");
        let p = pick(100, 0.2);
        assert_eq!(p.len(), 20);
        assert!(p.windows(2).all(|w| w[0] < w[1]), "ascending like the slab builder");
        assert!(p.iter().all(|&c| (c as usize) < 100));
    }
}
