//! The [`Recorder`] sink abstraction: counters, gauges and fixed-bucket
//! histograms.
//!
//! Instrumentation sites in the streaming stack write through this trait so
//! the cost of observability is chosen by the *installed sink*, not by the
//! call site:
//!
//! - [`NullRecorder`] is the disabled state. Every method body is empty and
//!   `#[inline]`, so a monomorphised call compiles to nothing and a dynamic
//!   call is a single indirect jump to a `ret`. Its [`Recorder::is_enabled`]
//!   returns `false`, which call sites use to skip *ambient* costs the sink
//!   cannot elide for them (e.g. reading the clock before an `observe`).
//! - [`MemoryRecorder`] aggregates in memory with bounded state: one `u64`
//!   per counter name, one `f64` per gauge name, one [`Histogram`] per
//!   histogram name. Names are `&'static str` so recording never allocates
//!   strings.
//!
//! Histograms use fixed, log-spaced bucket bounds chosen per quantity kind
//! ([`HistogramKind`]) — recording is a binary search over a dozen bounds,
//! O(1) memory, no reservoir. That matches the streaming story: telemetry
//! state must not grow with stream length.

use std::collections::BTreeMap;

/// Bucket upper bounds (inclusive) for latency histograms, in nanoseconds:
/// 1 µs … 4 s, log-spaced ×4. Values above the last bound land in the
/// overflow bucket.
pub const LATENCY_BOUNDS_NS: [u64; 12] = [
    1_000,
    4_000,
    16_000,
    64_000,
    256_000,
    1_000_000,
    4_000_000,
    16_000_000,
    64_000_000,
    256_000_000,
    1_000_000_000,
    4_000_000_000,
];

/// Bucket upper bounds (inclusive) for size histograms, in bytes:
/// 256 B … 64 MiB, log-spaced ×4.
pub const SIZE_BOUNDS_BYTES: [u64; 10] = [
    256,
    1_024,
    4_096,
    16_384,
    65_536,
    262_144,
    1 << 20,
    4 << 20,
    16 << 20,
    64 << 20,
];

/// Which fixed bucket layout a histogram observation belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistogramKind {
    /// Durations in nanoseconds (step latency, checkpoint encode/decode).
    LatencyNs,
    /// Sizes in bytes (snapshot/spill sizes).
    Bytes,
}

impl HistogramKind {
    /// The fixed bucket bounds for this kind.
    pub fn bounds(self) -> &'static [u64] {
        match self {
            HistogramKind::LatencyNs => &LATENCY_BOUNDS_NS,
            HistogramKind::Bytes => &SIZE_BOUNDS_BYTES,
        }
    }
}

/// A fixed-bucket histogram: `bounds.len() + 1` counts (the last is the
/// overflow bucket), plus exact count/sum/min/max. O(1) memory per metric.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    bounds: &'static [u64],
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Histogram {
    pub fn new(kind: HistogramKind) -> Self {
        let bounds = kind.bounds();
        Histogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Fold one observation in (binary search over the bucket bounds).
    pub fn record(&mut self, value: u64) {
        let b = self.bounds.partition_point(|&bound| bound < value);
        self.counts[b] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(value);
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Smallest observation, or 0 when empty.
    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean observation, or 0 when empty.
    pub fn mean(&self) -> u64 {
        if self.count == 0 { 0 } else { self.sum / self.count }
    }

    /// The fixed bucket upper bounds (the overflow bucket has no bound).
    pub fn bounds(&self) -> &'static [u64] {
        self.bounds
    }

    /// Per-bucket counts; `bucket_counts().len() == bounds().len() + 1`.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Upper bound of the bucket containing the `q`-quantile observation
    /// (`q` in `[0, 1]`); the exact max for the overflow bucket; 0 when
    /// empty. Coarse by construction — fine for dashboards, not for SLOs.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let rank = rank.max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }

    /// Fold another histogram in. Panics if the bucket layouts differ —
    /// merging incompatible layouts would silently misattribute counts.
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            std::ptr::eq(self.bounds, other.bounds),
            "histogram bucket layouts differ"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Sink for telemetry primitives. All names are `&'static str` so recording
/// never allocates; implementations must be cheap enough to sit on the
/// eviction/admission path (the per-step path is additionally gated by
/// [`crate::session::OnlineSession::enable_telemetry`]).
pub trait Recorder: Send {
    /// Add `delta` to the named monotone counter.
    fn counter(&mut self, name: &'static str, delta: u64);

    /// Set the named gauge to `value` (last write wins).
    fn gauge(&mut self, name: &'static str, value: f64);

    /// Fold `value` into the named fixed-bucket histogram of `kind`.
    fn observe(&mut self, name: &'static str, kind: HistogramKind, value: u64);

    /// Whether this sink keeps anything. Call sites use `false` to skip
    /// work the sink cannot elide (clock reads, size computations).
    fn is_enabled(&self) -> bool {
        true
    }
}

/// The disabled sink: every record is a no-op and [`Recorder::is_enabled`]
/// is `false`, so instrumented code skips clock reads too.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    #[inline]
    fn counter(&mut self, _name: &'static str, _delta: u64) {}

    #[inline]
    fn gauge(&mut self, _name: &'static str, _value: f64) {}

    #[inline]
    fn observe(&mut self, _name: &'static str, _kind: HistogramKind, _value: u64) {}

    #[inline]
    fn is_enabled(&self) -> bool {
        false
    }
}

/// In-memory aggregation: `BTreeMap` keyed by static name (deterministic
/// iteration order for snapshots and tests).
#[derive(Debug, Clone, Default)]
pub struct MemoryRecorder {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    histograms: BTreeMap<&'static str, Histogram>,
}

impl MemoryRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current value of a counter (0 if never written).
    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Current value of a gauge, if ever written.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// The named histogram, if any observation was recorded.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Counter names seen so far, in sorted order.
    pub fn counter_names(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.counters.keys().copied()
    }
}

impl Recorder for MemoryRecorder {
    fn counter(&mut self, name: &'static str, delta: u64) {
        *self.counters.entry(name).or_insert(0) += delta;
    }

    fn gauge(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    fn observe(&mut self, name: &'static str, kind: HistogramKind, value: u64) {
        self.histograms
            .entry(name)
            .or_insert_with(|| Histogram::new(kind))
            .record(value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_stats() {
        let mut h = Histogram::new(HistogramKind::LatencyNs);
        h.record(500); // below first bound → bucket 0
        h.record(1_000); // == first bound (inclusive) → bucket 0
        h.record(2_000); // bucket 1
        h.record(10_000_000_000); // above last bound → overflow bucket
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 500 + 1_000 + 2_000 + 10_000_000_000);
        assert_eq!(h.min(), 500);
        assert_eq!(h.max(), 10_000_000_000);
        assert_eq!(h.bucket_counts()[0], 2);
        assert_eq!(h.bucket_counts()[1], 1);
        assert_eq!(*h.bucket_counts().last().unwrap(), 1);
        assert_eq!(h.bucket_counts().len(), LATENCY_BOUNDS_NS.len() + 1);
    }

    #[test]
    fn histogram_quantile_is_bucket_bound() {
        let mut h = Histogram::new(HistogramKind::Bytes);
        for _ in 0..99 {
            h.record(100); // bucket 0, bound 256
        }
        h.record(2_000); // bucket 2, bound 4096
        assert_eq!(h.quantile(0.5), 256);
        assert_eq!(h.quantile(1.0), 4_096);
        assert_eq!(Histogram::new(HistogramKind::Bytes).quantile(0.5), 0);
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = Histogram::new(HistogramKind::LatencyNs);
        a.record(1_000);
        let mut b = Histogram::new(HistogramKind::LatencyNs);
        b.record(5_000);
        b.record(100);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min(), 100);
        assert_eq!(a.max(), 5_000);
    }

    #[test]
    fn null_recorder_is_disabled_noop() {
        let mut r = NullRecorder;
        assert!(!r.is_enabled());
        // No state to mutate; just pin that the calls are accepted.
        r.counter("x", 1);
        r.gauge("y", 2.0);
        r.observe("z", HistogramKind::LatencyNs, 3);
    }

    #[test]
    fn memory_recorder_aggregates() {
        let mut r = MemoryRecorder::new();
        assert!(r.is_enabled());
        r.counter("pool.evictions", 1);
        r.counter("pool.evictions", 2);
        r.gauge("pool.live_sessions", 3.0);
        r.gauge("pool.live_sessions", 2.0);
        r.observe("pool.evict_encode_ns", HistogramKind::LatencyNs, 10_000);
        r.observe("pool.evict_encode_ns", HistogramKind::LatencyNs, 20_000);
        assert_eq!(r.counter_value("pool.evictions"), 3);
        assert_eq!(r.counter_value("never"), 0);
        assert_eq!(r.gauge_value("pool.live_sessions"), Some(2.0));
        let h = r.histogram("pool.evict_encode_ns").unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.mean(), 15_000);
        assert_eq!(r.counter_names().collect::<Vec<_>>(), vec!["pool.evictions"]);
    }
}
