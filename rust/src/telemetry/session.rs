//! Per-session metric sampling: fold every step's observations into a
//! window, close the window every `sample_every` steps into a
//! [`MetricPoint`], and keep the points in a bounded ring.
//!
//! The sampled quantities are exactly the drifting series the paper's cost
//! model is stated in: activity sparsity α, pseudo-derivative sparsity β
//! (so β̃ = 1 − β), influence-panel occupancy, and per-phase MAC/word rates
//! (the `ω̃²β̃²n²p` influence-update term is
//! [`crate::metrics::Phase::InfluenceUpdate`]'s rate). Memory is O(ring
//! capacity) regardless of stream length — the streaming story applies to
//! the telemetry too.

use crate::metrics::{OpCounter, Phase, SparsityStats, NUM_PHASES};
use crate::session::StepOutcome;
use crate::telemetry::recorder::{Histogram, HistogramKind};
use crate::telemetry::ring::Ring;

/// Sampling knobs for [`SessionTelemetry`].
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Close a metrics window every this many steps (≥ 1).
    pub sample_every: u64,
    /// How many [`MetricPoint`]s the ring keeps (≥ 1).
    pub ring_capacity: usize,
    /// EWMA coefficient for the loss series: `ewma ← (1−a)·ewma + a·loss`.
    pub loss_ewma_alpha: f32,
    /// Ask the engine to measure influence-panel occupancy each step.
    /// Measurement is pure inspection — it charges no ops and perturbs no
    /// gradients — but it does scan the panel, so it costs wall time.
    pub measure_influence: bool,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            sample_every: 16,
            ring_capacity: 256,
            loss_ewma_alpha: 0.05,
            measure_influence: true,
        }
    }
}

impl TelemetryConfig {
    /// Clamp degenerate values (0 cadence / 0 capacity) up to 1.
    pub fn sanitized(mut self) -> Self {
        self.sample_every = self.sample_every.max(1);
        self.ring_capacity = self.ring_capacity.max(1);
        self
    }
}

/// One closed metrics window: means over `window_start..=step`.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricPoint {
    /// 1-based stream position of the first step in the window.
    pub window_start: u64,
    /// 1-based stream position of the last step in the window.
    pub step: u64,
    /// Mean activation sparsity α over the window.
    pub alpha: f32,
    /// Mean pseudo-derivative sparsity β over the window.
    pub beta: f32,
    /// Mean backward density β̃ = 1 − β.
    pub beta_tilde: f32,
    /// Mean influence-panel occupancy (1 − zero fraction), when measured.
    pub influence_occupancy: Option<f32>,
    /// Loss EWMA as of the window close (None until a supervised step).
    pub loss_ewma: Option<f32>,
    /// Per-phase MACs per step over the window ([`Phase::index`] order).
    pub macs_per_step: [u64; NUM_PHASES],
    /// Per-phase memory words per step over the window.
    pub words_per_step: [u64; NUM_PHASES],
    /// Total wall time the window's steps spent inside
    /// [`crate::session::OnlineSession::step`], in nanoseconds.
    pub window_latency_ns: u64,
}

impl MetricPoint {
    /// Steps folded into this window.
    pub fn window_len(&self) -> u64 {
        self.step - self.window_start + 1
    }

    /// Mean step latency over the window, ns.
    pub fn mean_step_latency_ns(&self) -> u64 {
        self.window_latency_ns / self.window_len().max(1)
    }
}

/// Streaming metric sampler owned by an [`crate::session::OnlineSession`]
/// when telemetry is enabled. See the module docs for what is sampled.
#[derive(Debug, Clone)]
pub struct SessionTelemetry {
    cfg: TelemetryConfig,
    /// Total units N across the stack (denominator for α/β).
    n_units: usize,
    /// Open-window sparsity accumulators.
    window: SparsityStats,
    window_steps: u64,
    window_latency_ns: u64,
    /// Per-phase MAC/word totals at the window open (rates are deltas).
    base_macs: [u64; NUM_PHASES],
    base_words: [u64; NUM_PHASES],
    loss_ewma: Option<f32>,
    /// Whole-session step-latency histogram (fixed buckets, O(1) memory).
    latency: Histogram,
    ring: Ring<MetricPoint>,
    /// Points not yet drained by a trace emitter.
    fresh: Vec<MetricPoint>,
    steps_seen: u64,
}

impl SessionTelemetry {
    /// `ops` is the session's op counter *at enable time*: telemetry can
    /// come on mid-stream (including after a resume), and rates must be
    /// deltas from that point, not from zero.
    pub fn new(cfg: TelemetryConfig, n_units: usize, ops: &OpCounter) -> Self {
        let cfg = cfg.sanitized();
        let ring = Ring::new(cfg.ring_capacity);
        let mut t = SessionTelemetry {
            cfg,
            n_units: n_units.max(1),
            window: SparsityStats::new(),
            window_steps: 0,
            window_latency_ns: 0,
            base_macs: [0; NUM_PHASES],
            base_words: [0; NUM_PHASES],
            loss_ewma: None,
            latency: Histogram::new(HistogramKind::LatencyNs),
            ring,
            fresh: Vec::new(),
            steps_seen: 0,
        };
        t.rebase_ops(ops);
        t
    }

    pub fn config(&self) -> &TelemetryConfig {
        &self.cfg
    }

    /// Fold one step in; closes a window (pushing a [`MetricPoint`]) every
    /// `sample_every` steps. Called by the session with the step's outcome,
    /// its wall time, and the session's cumulative op counter.
    pub fn on_step(&mut self, outcome: &StepOutcome, latency_ns: u64, ops: &OpCounter) {
        self.steps_seen += 1;
        self.window_steps += 1;
        self.window_latency_ns = self.window_latency_ns.saturating_add(latency_ns);
        self.latency.record(latency_ns);
        self.window.record_step(self.n_units, outcome.active_units, outcome.deriv_units);
        if let Some(zero_fraction) = outcome.influence_sparsity {
            self.window.record_influence(zero_fraction);
        }
        if let Some(loss) = outcome.loss {
            let a = self.cfg.loss_ewma_alpha;
            self.loss_ewma = Some(match self.loss_ewma {
                Some(e) => (1.0 - a) * e + a * loss,
                None => loss,
            });
        }
        if self.window_steps >= self.cfg.sample_every {
            self.close_window(outcome.step, ops);
        }
    }

    fn rebase_ops(&mut self, ops: &OpCounter) {
        for (i, ph) in Phase::all().iter().enumerate() {
            self.base_macs[i] = ops.macs_in(*ph);
            self.base_words[i] = ops.words_in(*ph);
        }
    }

    fn close_window(&mut self, step: u64, ops: &OpCounter) {
        let steps = self.window_steps.max(1);
        let mut macs_per_step = [0u64; NUM_PHASES];
        let mut words_per_step = [0u64; NUM_PHASES];
        for (i, ph) in Phase::all().iter().enumerate() {
            macs_per_step[i] = ops.macs_in(*ph).saturating_sub(self.base_macs[i]) / steps;
            words_per_step[i] = ops.words_in(*ph).saturating_sub(self.base_words[i]) / steps;
        }
        let influence_occupancy = if self.window.influence_observations() > 0 {
            Some(1.0 - self.window.influence_sparsity())
        } else {
            None
        };
        let point = MetricPoint {
            window_start: step + 1 - steps,
            step,
            alpha: self.window.alpha(),
            beta: self.window.beta(),
            beta_tilde: self.window.beta_tilde(),
            influence_occupancy,
            loss_ewma: self.loss_ewma,
            macs_per_step,
            words_per_step,
            window_latency_ns: self.window_latency_ns,
        };
        self.ring.push(point.clone());
        self.fresh.push(point);
        self.window.reset();
        self.window_steps = 0;
        self.window_latency_ns = 0;
        self.rebase_ops(ops);
    }

    /// Sampled points still in the ring, oldest → newest.
    pub fn points(&self) -> impl Iterator<Item = &MetricPoint> + '_ {
        self.ring.iter()
    }

    /// The most recent sampled point.
    pub fn latest_point(&self) -> Option<&MetricPoint> {
        self.ring.latest()
    }

    /// Points produced since the last drain (for live trace emission).
    /// Unlike the ring, this buffer is unbounded *between drains*; callers
    /// that enable telemetry must drain on their emit cadence.
    pub fn drain_new_points(&mut self) -> Vec<MetricPoint> {
        std::mem::take(&mut self.fresh)
    }

    /// Whole-session step-latency histogram.
    pub fn latency_histogram(&self) -> &Histogram {
        &self.latency
    }

    /// Current loss EWMA (None until the first supervised step).
    pub fn loss_ewma(&self) -> Option<f32> {
        self.loss_ewma
    }

    /// Steps folded in since telemetry was enabled.
    pub fn steps_seen(&self) -> u64 {
        self.steps_seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(step: u64, active: usize, deriv: usize, loss: Option<f32>) -> StepOutcome {
        StepOutcome {
            step,
            loss,
            active_units: active,
            deriv_units: deriv,
            ..StepOutcome::default()
        }
    }

    #[test]
    fn cadence_closes_windows_and_bounds_ring() {
        let cfg = TelemetryConfig {
            sample_every: 4,
            ring_capacity: 3,
            ..TelemetryConfig::default()
        };
        let ops = OpCounter::new();
        let mut t = SessionTelemetry::new(cfg, 8, &ops);
        for s in 1..=20 {
            t.on_step(&outcome(s, 4, 2, Some(1.0)), 1_000, &ops);
        }
        // 20 steps / cadence 4 = 5 points; ring keeps the last 3.
        assert_eq!(t.ring.len(), 3);
        let points: Vec<&MetricPoint> = t.points().collect();
        assert_eq!(points[0].window_start, 9);
        assert_eq!(points[0].step, 12);
        assert_eq!(points[2].step, 20);
        assert_eq!(points[2].window_len(), 4);
        // α = 1 - 4/8, β = 1 - 2/8 in every window
        assert!((points[2].alpha - 0.5).abs() < 1e-6);
        assert!((points[2].beta - 0.75).abs() < 1e-6);
        assert!((points[2].beta_tilde - 0.25).abs() < 1e-6);
        assert_eq!(points[2].window_latency_ns, 4_000);
        assert_eq!(points[2].mean_step_latency_ns(), 1_000);
        // drain sees all 5, then empties
        assert_eq!(t.drain_new_points().len(), 5);
        assert!(t.drain_new_points().is_empty());
        assert_eq!(t.latency_histogram().count(), 20);
        assert_eq!(t.steps_seen(), 20);
    }

    #[test]
    fn loss_ewma_tracks_supervised_steps_only() {
        let cfg = TelemetryConfig { sample_every: 2, loss_ewma_alpha: 0.5, ..Default::default() };
        let ops = OpCounter::new();
        let mut t = SessionTelemetry::new(cfg, 4, &ops);
        t.on_step(&outcome(1, 2, 2, None), 100, &ops);
        assert_eq!(t.loss_ewma(), None);
        t.on_step(&outcome(2, 2, 2, Some(2.0)), 100, &ops);
        assert_eq!(t.loss_ewma(), Some(2.0));
        t.on_step(&outcome(3, 2, 2, Some(1.0)), 100, &ops);
        assert!((t.loss_ewma().unwrap() - 1.5).abs() < 1e-6);
        let last = t.latest_point().unwrap();
        assert_eq!(last.loss_ewma, Some(2.0)); // closed at step 2
    }

    #[test]
    fn op_rates_are_window_deltas() {
        let cfg = TelemetryConfig { sample_every: 2, ..Default::default() };
        let mut ops = OpCounter::new();
        ops.macs(Phase::Forward, 100); // pre-telemetry history must not leak in
        let mut t = SessionTelemetry::new(cfg, 4, &ops);
        t.on_step(&outcome(1, 2, 2, None), 10, &ops);
        ops.macs(Phase::Forward, 8);
        ops.macs(Phase::InfluenceUpdate, 20);
        t.on_step(&outcome(2, 2, 2, None), 10, &ops);
        let p = t.latest_point().unwrap();
        assert_eq!(p.macs_per_step[Phase::Forward.index()], 4);
        assert_eq!(p.macs_per_step[Phase::InfluenceUpdate.index()], 10);
        // next window starts from the new baseline
        ops.macs(Phase::Forward, 6);
        t.on_step(&outcome(3, 2, 2, None), 10, &ops);
        t.on_step(&outcome(4, 2, 2, None), 10, &ops);
        let p = t.latest_point().unwrap();
        assert_eq!(p.macs_per_step[Phase::Forward.index()], 3);
    }

    #[test]
    fn influence_occupancy_present_only_when_measured() {
        let cfg = TelemetryConfig { sample_every: 1, ..Default::default() };
        let ops = OpCounter::new();
        let mut t = SessionTelemetry::new(cfg.clone(), 4, &ops);
        t.on_step(&outcome(1, 2, 2, None), 10, &ops);
        assert_eq!(t.latest_point().unwrap().influence_occupancy, None);
        let mut o = outcome(2, 2, 2, None);
        o.influence_sparsity = Some(0.75);
        t.on_step(&o, 10, &ops);
        let occ = t.latest_point().unwrap().influence_occupancy.unwrap();
        assert!((occ - 0.25).abs() < 1e-6);
    }
}
