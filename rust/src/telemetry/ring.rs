//! Bounded ring buffer for telemetry time series: `push` overwrites the
//! oldest entry once `capacity` is reached, so memory stays O(capacity)
//! however long the stream runs.

/// Fixed-capacity ring. Iteration yields entries oldest → newest.
#[derive(Debug, Clone)]
pub struct Ring<T> {
    buf: Vec<T>,
    cap: usize,
    /// Index of the oldest entry once the ring has wrapped.
    start: usize,
}

impl<T> Ring<T> {
    /// `cap` is clamped to at least 1.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Ring { buf: Vec::with_capacity(cap), cap, start: 0 }
    }

    /// Append, dropping the oldest entry when full.
    pub fn push(&mut self, value: T) {
        if self.buf.len() < self.cap {
            self.buf.push(value);
        } else {
            self.buf[self.start] = value;
            self.start = (self.start + 1) % self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &T> + '_ {
        let (tail, head) = self.buf.split_at(self.start);
        head.iter().chain(tail.iter())
    }

    /// The most recently pushed entry.
    pub fn latest(&self) -> Option<&T> {
        if self.buf.is_empty() {
            None
        } else if self.buf.len() < self.cap {
            self.buf.last()
        } else {
            let i = (self.start + self.cap - 1) % self.cap;
            self.buf.get(i)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_wraps_oldest_first() {
        let mut r = Ring::new(3);
        assert!(r.is_empty());
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec![2, 3, 4]);
        assert_eq!(r.latest(), Some(&4));
    }

    #[test]
    fn partial_fill_keeps_order() {
        let mut r = Ring::new(8);
        r.push(10);
        r.push(20);
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec![10, 20]);
        assert_eq!(r.latest(), Some(&20));
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = Ring::new(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.len(), 1);
        assert_eq!(r.latest(), Some(&2));
    }
}
