//! Structured trace: JSON-lines span/event/metrics records behind
//! `stream --trace <path>`, with an in-tree parser so the serialize→parse
//! round trip is testable without vendoring serde (mirrors
//! [`crate::bench::json`]).
//!
//! # Schema (`sparse-rtrl/trace/v1`)
//!
//! One JSON object per line, dispatched on `"type"`:
//!
//! - `meta` — first line of a trace: `schema`, `version`, `session`,
//!   `engine`, `hidden`, `layers`, `sample_every`.
//! - `metrics` — one closed sampling window ([`MetricPoint`]): `session`,
//!   `window_start`, `step`, `alpha`, `beta`, `beta_tilde`,
//!   `influence_occupancy` (number or null), `loss_ewma` (number or null),
//!   `macs_per_step` / `words_per_step` (objects keyed by
//!   [`crate::metrics::Phase`] name), `window_latency_ns`.
//! - `span` — a named region over a step range: `session`, `phase`,
//!   `step_start`, `step_end`, `duration_ns`.
//! - `event` — a point occurrence: `session`, `step`, `event` (one of
//!   [`TraceEventKind`]), optional `bytes` and `duration_ns` (number or
//!   null).
//!
//! Numbers follow the bench-report conventions: non-finite floats emit as
//! `null`, `u64`s emit as plain decimals (quantities here stay far below
//! the 2⁵³ integer-precision ceiling of JSON consumers).

use crate::bench::json::{escape, number32, parse, Json};
use crate::metrics::{Phase, NUM_PHASES};
use crate::telemetry::session::MetricPoint;
use std::io::Write;

/// Schema identifier carried in every `meta` record.
pub const TRACE_SCHEMA: &str = "sparse-rtrl/trace/v1";
/// Monotone trace-schema revision.
pub const TRACE_VERSION: u64 = 1;

/// Point occurrences a trace records besides metrics windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceEventKind {
    /// A parameter update was applied.
    Update,
    /// A sequence boundary was consumed.
    SequenceEnd,
    /// A checkpoint was written (`bytes`, `duration_ns` set).
    Checkpoint,
    /// A pool eviction spilled a session (`bytes`, `duration_ns` set).
    Evict,
    /// A pool admission restored a session (`duration_ns` set).
    Admit,
}

impl TraceEventKind {
    pub fn name(self) -> &'static str {
        match self {
            TraceEventKind::Update => "update",
            TraceEventKind::SequenceEnd => "sequence_end",
            TraceEventKind::Checkpoint => "checkpoint",
            TraceEventKind::Evict => "evict",
            TraceEventKind::Admit => "admit",
        }
    }

    pub fn from_name(name: &str) -> Option<TraceEventKind> {
        match name {
            "update" => Some(TraceEventKind::Update),
            "sequence_end" => Some(TraceEventKind::SequenceEnd),
            "checkpoint" => Some(TraceEventKind::Checkpoint),
            "evict" => Some(TraceEventKind::Evict),
            "admit" => Some(TraceEventKind::Admit),
            _ => None,
        }
    }
}

/// One line of a trace file. See the module docs for the field-level
/// schema.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceRecord {
    Meta {
        session: String,
        engine: String,
        hidden: u64,
        layers: u64,
        sample_every: u64,
    },
    Metrics {
        session: String,
        point: MetricPoint,
    },
    Span {
        session: String,
        phase: String,
        step_start: u64,
        step_end: u64,
        duration_ns: u64,
    },
    Event {
        session: String,
        step: u64,
        event: TraceEventKind,
        bytes: Option<u64>,
        duration_ns: Option<u64>,
    },
}

fn opt_num32(x: Option<f32>) -> String {
    match x {
        Some(v) => number32(v),
        None => "null".to_string(),
    }
}

fn opt_u64(x: Option<u64>) -> String {
    match x {
        Some(v) => v.to_string(),
        None => "null".to_string(),
    }
}

fn phase_obj(per_step: &[u64; NUM_PHASES]) -> String {
    let mut s = String::from("{");
    for (i, ph) in Phase::all().iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("\"{}\": {}", ph.name(), per_step[i]));
    }
    s.push('}');
    s
}

fn parse_phase_obj(v: &Json, key: &str) -> Result<[u64; NUM_PHASES], String> {
    let obj = v.get(key).ok_or_else(|| format!("missing {key:?}"))?;
    let mut out = [0u64; NUM_PHASES];
    for (i, ph) in Phase::all().iter().enumerate() {
        out[i] = obj
            .get(ph.name())
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("{key:?} missing phase {:?}", ph.name()))?;
    }
    Ok(out)
}

fn req_str(v: &Json, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string {key:?}"))
}

fn req_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key).and_then(Json::as_u64).ok_or_else(|| format!("missing integer {key:?}"))
}

fn req_f32(v: &Json, key: &str) -> Result<f32, String> {
    v.get(key)
        .and_then(Json::as_f64)
        .map(|x| x as f32)
        .ok_or_else(|| format!("missing number {key:?}"))
}

/// `key` absent or `null` → `None`; a number → `Some`.
fn opt_f32_of(v: &Json, key: &str) -> Result<Option<f32>, String> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => {
            x.as_f64().map(|f| Some(f as f32)).ok_or_else(|| format!("{key:?} is not a number"))
        }
    }
}

fn opt_u64_of(v: &Json, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x.as_u64().map(Some).ok_or_else(|| format!("{key:?} is not an integer")),
    }
}

impl TraceRecord {
    /// The session id every record carries.
    pub fn session(&self) -> &str {
        match self {
            TraceRecord::Meta { session, .. }
            | TraceRecord::Metrics { session, .. }
            | TraceRecord::Span { session, .. }
            | TraceRecord::Event { session, .. } => session,
        }
    }

    /// Render as one JSON line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        match self {
            TraceRecord::Meta { session, engine, hidden, layers, sample_every } => format!(
                "{{\"type\": \"meta\", \"schema\": \"{}\", \"version\": {}, \
                 \"session\": \"{}\", \"engine\": \"{}\", \"hidden\": {}, \"layers\": {}, \
                 \"sample_every\": {}}}",
                escape(TRACE_SCHEMA),
                TRACE_VERSION,
                escape(session),
                escape(engine),
                hidden,
                layers,
                sample_every
            ),
            TraceRecord::Metrics { session, point: p } => format!(
                "{{\"type\": \"metrics\", \"session\": \"{}\", \"window_start\": {}, \
                 \"step\": {}, \"alpha\": {}, \"beta\": {}, \"beta_tilde\": {}, \
                 \"influence_occupancy\": {}, \"loss_ewma\": {}, \"macs_per_step\": {}, \
                 \"words_per_step\": {}, \"window_latency_ns\": {}}}",
                escape(session),
                p.window_start,
                p.step,
                number32(p.alpha),
                number32(p.beta),
                number32(p.beta_tilde),
                opt_num32(p.influence_occupancy),
                opt_num32(p.loss_ewma),
                phase_obj(&p.macs_per_step),
                phase_obj(&p.words_per_step),
                p.window_latency_ns
            ),
            TraceRecord::Span { session, phase, step_start, step_end, duration_ns } => format!(
                "{{\"type\": \"span\", \"session\": \"{}\", \"phase\": \"{}\", \
                 \"step_start\": {}, \"step_end\": {}, \"duration_ns\": {}}}",
                escape(session),
                escape(phase),
                step_start,
                step_end,
                duration_ns
            ),
            TraceRecord::Event { session, step, event, bytes, duration_ns } => format!(
                "{{\"type\": \"event\", \"session\": \"{}\", \"step\": {}, \
                 \"event\": \"{}\", \"bytes\": {}, \"duration_ns\": {}}}",
                escape(session),
                step,
                event.name(),
                opt_u64(*bytes),
                opt_u64(*duration_ns)
            ),
        }
    }

    /// Parse one JSON line. Errors describe the first schema violation.
    pub fn from_json_line(line: &str) -> Result<TraceRecord, String> {
        let v = parse(line)?;
        let ty = req_str(&v, "type")?;
        match ty.as_str() {
            "meta" => {
                let schema = req_str(&v, "schema")?;
                if schema != TRACE_SCHEMA {
                    return Err(format!("unknown trace schema {schema:?}"));
                }
                let version = req_u64(&v, "version")?;
                if version != TRACE_VERSION {
                    return Err(format!(
                        "trace version {version} unsupported (this build reads {TRACE_VERSION})"
                    ));
                }
                Ok(TraceRecord::Meta {
                    session: req_str(&v, "session")?,
                    engine: req_str(&v, "engine")?,
                    hidden: req_u64(&v, "hidden")?,
                    layers: req_u64(&v, "layers")?,
                    sample_every: req_u64(&v, "sample_every")?,
                })
            }
            "metrics" => {
                let point = MetricPoint {
                    window_start: req_u64(&v, "window_start")?,
                    step: req_u64(&v, "step")?,
                    alpha: req_f32(&v, "alpha")?,
                    beta: req_f32(&v, "beta")?,
                    beta_tilde: req_f32(&v, "beta_tilde")?,
                    influence_occupancy: opt_f32_of(&v, "influence_occupancy")?,
                    loss_ewma: opt_f32_of(&v, "loss_ewma")?,
                    macs_per_step: parse_phase_obj(&v, "macs_per_step")?,
                    words_per_step: parse_phase_obj(&v, "words_per_step")?,
                    window_latency_ns: req_u64(&v, "window_latency_ns")?,
                };
                if point.step < point.window_start {
                    return Err(format!(
                        "metrics window ends at {} before it starts at {}",
                        point.step, point.window_start
                    ));
                }
                Ok(TraceRecord::Metrics { session: req_str(&v, "session")?, point })
            }
            "span" => Ok(TraceRecord::Span {
                session: req_str(&v, "session")?,
                phase: req_str(&v, "phase")?,
                step_start: req_u64(&v, "step_start")?,
                step_end: req_u64(&v, "step_end")?,
                duration_ns: req_u64(&v, "duration_ns")?,
            }),
            "event" => {
                let name = req_str(&v, "event")?;
                let event = TraceEventKind::from_name(&name)
                    .ok_or_else(|| format!("unknown event kind {name:?}"))?;
                Ok(TraceRecord::Event {
                    session: req_str(&v, "session")?,
                    step: req_u64(&v, "step")?,
                    event,
                    bytes: opt_u64_of(&v, "bytes")?,
                    duration_ns: opt_u64_of(&v, "duration_ns")?,
                })
            }
            other => Err(format!("unknown record type {other:?}")),
        }
    }
}

/// Parse a whole trace file. Blank lines are skipped; errors are prefixed
/// with the 1-based line number. The first non-blank line must be a `meta`
/// record — that is what makes a file *a trace* rather than arbitrary
/// JSON-lines.
pub fn parse_trace(text: &str) -> Result<Vec<TraceRecord>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rec = TraceRecord::from_json_line(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        if out.is_empty() && !matches!(rec, TraceRecord::Meta { .. }) {
            return Err(format!("line {}: trace must open with a meta record", i + 1));
        }
        out.push(rec);
    }
    Ok(out)
}

/// Streaming JSON-lines writer: one [`TraceRecord`] per line, flushed on
/// drop via the inner writer's own buffering discipline.
pub struct TraceSink<W: Write> {
    out: W,
    records: u64,
}

impl<W: Write> TraceSink<W> {
    pub fn new(out: W) -> Self {
        TraceSink { out, records: 0 }
    }

    pub fn emit(&mut self, rec: &TraceRecord) -> std::io::Result<()> {
        self.out.write_all(rec.to_json_line().as_bytes())?;
        self.out.write_all(b"\n")?;
        self.records += 1;
        Ok(())
    }

    /// Records emitted so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<TraceRecord> {
        vec![
            TraceRecord::Meta {
                session: "s0".into(),
                engine: "rtrl-both".into(),
                hidden: 32,
                layers: 1,
                sample_every: 4,
            },
            TraceRecord::Metrics {
                session: "s0".into(),
                point: MetricPoint {
                    window_start: 1,
                    step: 4,
                    alpha: 0.5,
                    beta: 0.25,
                    beta_tilde: 0.75,
                    influence_occupancy: Some(0.8),
                    loss_ewma: None,
                    macs_per_step: [10, 20, 30, 40, 50, 60],
                    words_per_step: [1, 2, 3, 4, 5, 6],
                    window_latency_ns: 123_456,
                },
            },
            TraceRecord::Span {
                session: "s0".into(),
                phase: "steps".into(),
                step_start: 1,
                step_end: 4,
                duration_ns: 123_456,
            },
            TraceRecord::Event {
                session: "s0".into(),
                step: 4,
                event: TraceEventKind::Evict,
                bytes: Some(2_048),
                duration_ns: Some(9_999),
            },
            TraceRecord::Event {
                session: "s0".into(),
                step: 4,
                event: TraceEventKind::Update,
                bytes: None,
                duration_ns: None,
            },
        ]
    }

    #[test]
    fn round_trip_through_sink_and_parser() {
        let records = sample_records();
        let mut buf = Vec::new();
        {
            let mut sink = TraceSink::new(&mut buf);
            for r in &records {
                sink.emit(r).unwrap();
            }
            assert_eq!(sink.records(), records.len() as u64);
        }
        let text = String::from_utf8(buf).unwrap();
        let parsed = parse_trace(&text).unwrap();
        assert_eq!(parsed, records);
    }

    #[test]
    fn trace_must_open_with_meta() {
        let line = sample_records()[3].to_json_line();
        let err = parse_trace(&line).unwrap_err();
        assert!(err.contains("meta"), "{err}");
        assert!(err.starts_with("line 1:"), "{err}");
    }

    #[test]
    fn schema_violations_name_the_field() {
        // a metrics record missing a phase in macs_per_step
        let bad = r#"{"type": "metrics", "session": "s0", "window_start": 1, "step": 4,
            "alpha": 0.5, "beta": 0.5, "beta_tilde": 0.5, "influence_occupancy": null,
            "loss_ewma": null, "macs_per_step": {"forward": 1}, "words_per_step": {},
            "window_latency_ns": 1}"#
            .replace('\n', " ");
        let err = TraceRecord::from_json_line(&bad).unwrap_err();
        assert!(err.contains("macs_per_step"), "{err}");
        // an event with an unknown kind
        let bad = r#"{"type": "event", "session": "s0", "step": 1, "event": "compact"}"#;
        let err = TraceRecord::from_json_line(bad).unwrap_err();
        assert!(err.contains("compact"), "{err}");
        // an unknown schema in meta
        let bad = r#"{"type": "meta", "schema": "other/v9", "version": 1, "session": "s",
            "engine": "e", "hidden": 1, "layers": 1, "sample_every": 1}"#
            .replace('\n', " ");
        let err = TraceRecord::from_json_line(&bad).unwrap_err();
        assert!(err.contains("other/v9"), "{err}");
    }

    #[test]
    fn inverted_metrics_window_rejected() {
        let bad = r#"{"type": "metrics", "session": "s0", "window_start": 9, "step": 4,
            "alpha": 0, "beta": 0, "beta_tilde": 1, "influence_occupancy": null,
            "loss_ewma": null,
            "macs_per_step": {"forward": 0, "jacobian": 0, "immediate": 0,
            "influence_update": 0, "grad_combine": 0, "optimizer": 0},
            "words_per_step": {"forward": 0, "jacobian": 0, "immediate": 0,
            "influence_update": 0, "grad_combine": 0, "optimizer": 0},
            "window_latency_ns": 1}"#
            .replace('\n', " ");
        let err = TraceRecord::from_json_line(&bad).unwrap_err();
        assert!(err.contains("before it starts"), "{err}");
    }

    #[test]
    fn blank_lines_skipped_and_errors_carry_line_numbers() {
        let meta = sample_records()[0].to_json_line();
        let text = format!("{meta}\n\nnot json\n");
        let err = parse_trace(&text).unwrap_err();
        assert!(err.starts_with("line 3:"), "{err}");
    }
}
