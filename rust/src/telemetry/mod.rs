//! Zero-cost-when-disabled observability for the streaming stack.
//!
//! The paper's cost model is stated in quantities that *drift* over a
//! stream — activity sparsity α, pseudo-derivative sparsity β, influence
//! occupancy, per-phase op rates — so they are only verifiable in
//! production as time series. This module makes them first-class runtime
//! signals:
//!
//! - [`recorder`] — the [`Recorder`] sink trait (counters, gauges,
//!   fixed-bucket histograms), with [`NullRecorder`] (disabled; no-ops) and
//!   [`MemoryRecorder`] (bounded in-memory aggregation).
//! - [`session`] — [`SessionTelemetry`]: per-session sampling of α/β/β̃,
//!   influence occupancy, loss EWMA and per-phase MAC/word rates on a
//!   configurable cadence into bounded rings ([`ring::Ring`]).
//! - [`trace`] — the JSON-lines structured trace
//!   ([`trace::TRACE_SCHEMA`]): span/event/metrics records behind
//!   `stream --trace`, with an in-tree parser and round-trip tests.
//! - [`snapshot`] — [`TelemetrySnapshot`]: pool-level aggregation
//!   (admissions, evictions, spill bytes, resume latency) serialized like
//!   the bench report and rendered by the `stats` subcommand.
//!
//! # Disabled means off
//!
//! Telemetry is opt-in per session
//! ([`crate::session::OnlineSession::enable_telemetry`]) and per pool
//! ([`crate::session::SessionPool::enable_telemetry`]). When off, the
//! per-step cost is one `Option` discriminant test — no clock reads, no
//! sampling, no allocation — and results are bit-identical to a build that
//! never had telemetry (pinned by `tests/telemetry.rs`). When on,
//! *results are still bit-identical*: every sampled quantity is pure
//! inspection, charged zero ops.

pub mod recorder;
pub mod ring;
pub mod session;
pub mod snapshot;
pub mod trace;

pub use recorder::{Histogram, HistogramKind, MemoryRecorder, NullRecorder, Recorder};
pub use session::{MetricPoint, SessionTelemetry, TelemetryConfig};
pub use snapshot::{HistogramSummary, SessionStats, TelemetrySnapshot};
pub use trace::{parse_trace, TraceEventKind, TraceRecord, TraceSink, TRACE_SCHEMA, TRACE_VERSION};

/// Canonical metric names recorded by the pool (one place, so snapshot
/// readers and instrumentation sites cannot drift apart).
pub mod names {
    /// Counter: sessions admitted (restored) into a pool.
    pub const POOL_ADMISSIONS: &str = "pool.admissions";
    /// Counter: sessions evicted (spilled) from a pool.
    pub const POOL_EVICTIONS: &str = "pool.evictions";
    /// Counter: total bytes written by evictions.
    pub const POOL_SPILL_BYTES: &str = "pool.spill_bytes";
    /// Gauge: live sessions after the latest pool mutation.
    pub const POOL_LIVE_SESSIONS: &str = "pool.live_sessions";
    /// Histogram (latency): checkpoint encode wall time on eviction.
    pub const POOL_EVICT_ENCODE_NS: &str = "pool.evict_encode_ns";
    /// Histogram (latency): read+decode+resume wall time on admission.
    pub const POOL_RESUME_DECODE_NS: &str = "pool.resume_decode_ns";
    /// Histogram (bytes): serialized snapshot sizes on eviction.
    pub const POOL_SPILL_SIZE_BYTES: &str = "pool.spill_size_bytes";

    /// Counter: scheduler rounds run by a serve loop.
    pub const SERVE_ROUNDS: &str = "serve.rounds";
    /// Counter: events applied by a serve loop (steps + control events).
    pub const SERVE_EVENTS: &str = "serve.events";
    /// Counter: step events that ran through a fused shared-weight group.
    pub const SERVE_FUSED_STEPS: &str = "serve.fused_steps";
    /// Counter: step events that ran per-session.
    pub const SERVE_SOLO_STEPS: &str = "serve.solo_steps";
    /// Histogram (latency): per-step wall time inside scheduler rounds.
    pub const SERVE_STEP_NS: &str = "serve.step_ns";
}
