//! [`TelemetrySnapshot`]: a point-in-time view of a
//! [`crate::session::SessionPool`]'s aggregated telemetry — admissions,
//! evictions, spill bytes, evict/resume latency summaries, and one row per
//! live session — serialized as versioned JSON through the same in-tree
//! conventions as the bench report (schema string + monotone version,
//! hand-rolled writer, [`crate::bench::json::parse`] reader). This is an
//! observability document, not a checkpoint: nothing in it restores state,
//! so floats emit human-readable, not as bit patterns.

use crate::bench::json::{number32, parse, Json};
use crate::telemetry::recorder::Histogram;

/// Schema identifier for serialized snapshots.
pub const STATS_SCHEMA: &str = "sparse-rtrl/telemetry/v1";
/// Monotone snapshot-schema revision.
pub const STATS_VERSION: u64 = 1;

/// Fixed-bucket histogram condensed to the fields a dashboard needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HistogramSummary {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    /// Coarse bucket-bound quantiles (see [`Histogram::quantile`]).
    pub p50: u64,
    pub p99: u64,
}

impl HistogramSummary {
    pub fn from_histogram(h: &Histogram) -> Self {
        HistogramSummary {
            count: h.count(),
            sum: h.sum(),
            min: h.min(),
            max: h.max(),
            p50: h.quantile(0.5),
            p99: h.quantile(0.99),
        }
    }

    /// Mean observation, or 0 when empty.
    pub fn mean(&self) -> u64 {
        if self.count == 0 { 0 } else { self.sum / self.count }
    }

    fn to_json(self) -> String {
        format!(
            "{{\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \"p50\": {}, \"p99\": {}}}",
            self.count, self.sum, self.min, self.max, self.p50, self.p99
        )
    }

    fn from_json(v: &Json, key: &str) -> Result<Self, String> {
        let o = v.get(key).ok_or_else(|| format!("missing {key:?}"))?;
        let f = |k: &str| {
            o.get(k).and_then(Json::as_u64).ok_or_else(|| format!("{key:?} missing {k:?}"))
        };
        Ok(HistogramSummary {
            count: f("count")?,
            sum: f("sum")?,
            min: f("min")?,
            max: f("max")?,
            p50: f("p50")?,
            p99: f("p99")?,
        })
    }
}

/// One live session's row in a snapshot. `alpha`/`beta`/`loss_ewma` come
/// from the session's latest sampled [`crate::telemetry::MetricPoint`] and
/// are absent when per-session telemetry is off or no window has closed.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionStats {
    pub index: u64,
    pub steps: u64,
    pub supervised_steps: u64,
    pub updates_applied: u64,
    pub loss_ewma: Option<f32>,
    pub alpha: Option<f32>,
    pub beta: Option<f32>,
    /// Sampled points currently held in the session's ring.
    pub points: u64,
}

fn opt32(x: Option<f32>) -> String {
    match x {
        Some(v) => number32(v),
        None => "null".to_string(),
    }
}

fn opt_f32_of(v: &Json, key: &str) -> Result<Option<f32>, String> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => {
            x.as_f64().map(|f| Some(f as f32)).ok_or_else(|| format!("{key:?} is not a number"))
        }
    }
}

fn req_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key).and_then(Json::as_u64).ok_or_else(|| format!("missing integer {key:?}"))
}

impl SessionStats {
    fn to_json(&self) -> String {
        format!(
            "{{\"index\": {}, \"steps\": {}, \"supervised_steps\": {}, \"updates_applied\": {}, \
             \"loss_ewma\": {}, \"alpha\": {}, \"beta\": {}, \"points\": {}}}",
            self.index,
            self.steps,
            self.supervised_steps,
            self.updates_applied,
            opt32(self.loss_ewma),
            opt32(self.alpha),
            opt32(self.beta),
            self.points
        )
    }

    fn from_json(v: &Json) -> Result<Self, String> {
        Ok(SessionStats {
            index: req_u64(v, "index")?,
            steps: req_u64(v, "steps")?,
            supervised_steps: req_u64(v, "supervised_steps")?,
            updates_applied: req_u64(v, "updates_applied")?,
            loss_ewma: opt_f32_of(v, "loss_ewma")?,
            alpha: opt_f32_of(v, "alpha")?,
            beta: opt_f32_of(v, "beta")?,
            points: req_u64(v, "points")?,
        })
    }
}

/// Point-in-time pool telemetry. Produced by
/// [`crate::session::SessionPool::telemetry_snapshot`]; renderable by the
/// `stats` subcommand.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TelemetrySnapshot {
    pub live_sessions: u64,
    pub workers: u64,
    pub admissions: u64,
    pub evictions: u64,
    /// Total bytes spilled by evictions.
    pub spill_bytes: u64,
    /// Checkpoint-encode wall time on the eviction path.
    pub evict_encode_ns: HistogramSummary,
    /// Read+decode+resume wall time on the admission path.
    pub resume_decode_ns: HistogramSummary,
    pub sessions: Vec<SessionStats>,
}

impl TelemetrySnapshot {
    /// Serialize (multi-line, human-diffable, same conventions as the bench
    /// report).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": \"{STATS_SCHEMA}\",\n"));
        s.push_str(&format!("  \"version\": {STATS_VERSION},\n"));
        s.push_str(&format!("  \"live_sessions\": {},\n", self.live_sessions));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"admissions\": {},\n", self.admissions));
        s.push_str(&format!("  \"evictions\": {},\n", self.evictions));
        s.push_str(&format!("  \"spill_bytes\": {},\n", self.spill_bytes));
        s.push_str(&format!("  \"evict_encode_ns\": {},\n", self.evict_encode_ns.to_json()));
        s.push_str(&format!("  \"resume_decode_ns\": {},\n", self.resume_decode_ns.to_json()));
        s.push_str("  \"sessions\": [\n");
        for (i, sess) in self.sessions.iter().enumerate() {
            s.push_str("    ");
            s.push_str(&sess.to_json());
            if i + 1 < self.sessions.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse a serialized snapshot, rejecting unknown schemas/versions.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = parse(text)?;
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing string \"schema\"".to_string())?;
        if schema != STATS_SCHEMA {
            return Err(format!("unknown telemetry schema {schema:?}"));
        }
        let version = req_u64(&v, "version")?;
        if version != STATS_VERSION {
            return Err(format!(
                "telemetry snapshot version {version} unsupported (this build reads {STATS_VERSION})"
            ));
        }
        let sessions = v
            .get("sessions")
            .and_then(Json::as_arr)
            .ok_or_else(|| "missing array \"sessions\"".to_string())?
            .iter()
            .enumerate()
            .map(|(i, s)| SessionStats::from_json(s).map_err(|e| format!("sessions[{i}]: {e}")))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TelemetrySnapshot {
            live_sessions: req_u64(&v, "live_sessions")?,
            workers: req_u64(&v, "workers")?,
            admissions: req_u64(&v, "admissions")?,
            evictions: req_u64(&v, "evictions")?,
            spill_bytes: req_u64(&v, "spill_bytes")?,
            evict_encode_ns: HistogramSummary::from_json(&v, "evict_encode_ns")?,
            resume_decode_ns: HistogramSummary::from_json(&v, "resume_decode_ns")?,
            sessions,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::recorder::HistogramKind;

    #[test]
    fn snapshot_round_trips() {
        let mut h = Histogram::new(HistogramKind::LatencyNs);
        h.record(5_000);
        h.record(50_000);
        let snap = TelemetrySnapshot {
            live_sessions: 2,
            workers: 4,
            admissions: 1,
            evictions: 3,
            spill_bytes: 6_144,
            evict_encode_ns: HistogramSummary::from_histogram(&h),
            resume_decode_ns: HistogramSummary::default(),
            sessions: vec![
                SessionStats {
                    index: 0,
                    steps: 100,
                    supervised_steps: 30,
                    updates_applied: 30,
                    loss_ewma: Some(0.625),
                    alpha: Some(0.5),
                    beta: Some(0.75),
                    points: 6,
                },
                SessionStats {
                    index: 1,
                    steps: 10,
                    supervised_steps: 0,
                    updates_applied: 0,
                    loss_ewma: None,
                    alpha: None,
                    beta: None,
                    points: 0,
                },
            ],
        };
        let text = snap.to_json();
        let back = TelemetrySnapshot::from_json(&text).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.evict_encode_ns.count, 2);
        assert_eq!(back.evict_encode_ns.mean(), 27_500);
    }

    #[test]
    fn wrong_schema_or_version_rejected() {
        let snap = TelemetrySnapshot::default();
        let text = snap.to_json().replace(STATS_SCHEMA, "sparse-rtrl/other/v1");
        assert!(TelemetrySnapshot::from_json(&text).unwrap_err().contains("other"));
        let text = snap.to_json().replace("\"version\": 1", "\"version\": 99");
        assert!(TelemetrySnapshot::from_json(&text).unwrap_err().contains("99"));
    }

    #[test]
    fn malformed_session_rows_name_their_index() {
        let snap = TelemetrySnapshot {
            sessions: vec![SessionStats {
                index: 0,
                steps: 1,
                supervised_steps: 0,
                updates_applied: 0,
                loss_ewma: None,
                alpha: None,
                beta: None,
                points: 0,
            }],
            ..TelemetrySnapshot::default()
        };
        let text = snap.to_json().replace("\"steps\": 1", "\"steps\": \"one\"");
        let err = TelemetrySnapshot::from_json(&text).unwrap_err();
        assert!(err.contains("sessions[0]"), "{err}");
    }
}
