//! Plain SGD with optional momentum (baseline / ablation optimizer).

use super::Optimizer;

#[derive(Debug, Clone)]
pub struct Sgd {
    lr: f32,
    momentum: f32,
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(dim: usize, lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum, velocity: vec![0.0; dim] }
    }
}

impl Optimizer for Sgd {
    fn update(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.velocity.len());
        for i in 0..params.len() {
            self.velocity[i] = self.momentum * self.velocity[i] + grads[i];
            params[i] -= self.lr * self.velocity[i];
        }
    }

    fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_gradient() {
        let mut x = vec![10.0f32];
        let mut sgd = Sgd::new(1, 0.1, 0.0);
        for _ in 0..200 {
            let g = vec![2.0 * x[0]];
            sgd.update(&mut x, &g);
        }
        assert!(x[0].abs() < 1e-3);
    }

    #[test]
    fn momentum_accelerates() {
        let run = |mu: f32| {
            let mut x = vec![10.0f32];
            let mut sgd = Sgd::new(1, 0.01, mu);
            for _ in 0..50 {
                let g = vec![2.0 * x[0]];
                sgd.update(&mut x, &g);
            }
            x[0]
        };
        assert!(run(0.9).abs() < run(0.0).abs());
    }
}
