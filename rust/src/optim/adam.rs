//! Adam (Kingma & Ba, 2015) — the optimizer used in the paper's experiments.

use super::Optimizer;

/// Serializable Adam state — first/second moments plus the step count.
/// Session checkpoints carry this so a resumed stream continues with the
/// exact same bias correction and per-parameter scaling.
#[derive(Debug, Clone, PartialEq)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

/// Adam with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    /// Paper-default hyperparameters (lr configurable).
    pub fn new(dim: usize, lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; dim], v: vec![0.0; dim], t: 0 }
    }

    pub fn with_betas(dim: usize, lr: f32, beta1: f32, beta2: f32) -> Self {
        Adam { lr, beta1, beta2, eps: 1e-8, m: vec![0.0; dim], v: vec![0.0; dim], t: 0 }
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    /// Reset the moments of specific parameters (used when dynamic rewiring
    /// swaps connections: a grown parameter must not inherit the dropped
    /// one's momentum/variance).
    pub fn reset_indices(&mut self, indices: &[usize]) {
        for &i in indices {
            self.m[i] = 0.0;
            self.v[i] = 0.0;
        }
    }

    /// Snapshot the moments + step count (session checkpoints).
    pub fn save_state(&self) -> AdamState {
        AdamState { m: self.m.clone(), v: self.v.clone(), t: self.t }
    }

    /// Restore a [`Adam::save_state`] snapshot; errors on dimension mismatch.
    pub fn load_state(&mut self, s: &AdamState) -> Result<(), String> {
        if s.m.len() != self.m.len() || s.v.len() != self.v.len() {
            return Err(format!(
                "Adam state over {} params cannot restore into optimizer over {}",
                s.m.len(),
                self.m.len()
            ));
        }
        self.m.copy_from_slice(&s.m);
        self.v.copy_from_slice(&s.v);
        self.t = s.t;
        Ok(())
    }
}

impl Optimizer for Adam {
    fn update(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let step = self.lr * bc2.sqrt() / bc1;
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            params[i] -= step * self.m[i] / (self.v[i].sqrt() + self.eps);
        }
    }

    fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = 0.5(x-3)², grad = x-3
        let mut x = vec![0.0f32];
        let mut adam = Adam::new(1, 0.1);
        for _ in 0..500 {
            let g = vec![x[0] - 3.0];
            adam.update(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "x={}", x[0]);
    }

    #[test]
    fn zero_grad_means_no_motion() {
        let mut x = vec![1.0f32, -2.0];
        let orig = x.clone();
        let mut adam = Adam::new(2, 0.1);
        for _ in 0..10 {
            adam.update(&mut x, &[0.0, 0.0]);
        }
        assert_eq!(x, orig, "masked params must not drift under zero grads");
    }

    #[test]
    fn reset_indices_only_touches_listed() {
        let mut x = vec![0.0f32, 0.0];
        let mut adam = Adam::new(2, 0.1);
        adam.update(&mut x, &[1.0, 1.0]);
        adam.reset_indices(&[0]);
        assert_eq!(adam.m[0], 0.0);
        assert!(adam.m[1] != 0.0);
    }

    #[test]
    fn reset_clears_state() {
        let mut x = vec![0.0f32];
        let mut adam = Adam::new(1, 0.1);
        adam.update(&mut x, &[1.0]);
        adam.reset();
        assert_eq!(adam.t, 0);
        assert_eq!(adam.m[0], 0.0);
    }

    #[test]
    fn save_load_resumes_identical_trajectory() {
        let grads = [[0.4f32, -1.0], [0.2, 0.3], [-0.6, 0.1]];
        // uninterrupted
        let mut x1 = vec![0.1f32, -0.2];
        let mut a1 = Adam::new(2, 0.05);
        for g in &grads {
            a1.update(&mut x1, g);
        }
        // interrupted after step 1, state carried across a fresh optimizer
        let mut x2 = vec![0.1f32, -0.2];
        let mut a2 = Adam::new(2, 0.05);
        a2.update(&mut x2, &grads[0]);
        let mut a3 = Adam::new(2, 0.05);
        a3.load_state(&a2.save_state()).unwrap();
        for g in &grads[1..] {
            a3.update(&mut x2, g);
        }
        assert_eq!(x1, x2, "resumed Adam diverged");
        assert!(a3.load_state(&Adam::new(3, 0.05).save_state()).is_err());
    }

    #[test]
    fn first_step_size_is_lr() {
        // with bias correction, |Δx| of the first step ≈ lr for any grad scale
        for g in [0.001f32, 1.0, 1000.0] {
            let mut x = vec![0.0f32];
            let mut adam = Adam::new(1, 0.1);
            adam.update(&mut x, &[g]);
            assert!((x[0].abs() - 0.1).abs() < 1e-3, "g={g} dx={}", x[0]);
        }
    }
}
