//! Optimizers: Adam (the paper's choice, §6) and SGD.
//!
//! Optimizers operate on flat `&mut [f32]` parameter buffers so the same
//! instance can drive cell and readout parameters. Masked (structurally
//! zero) parameters receive exactly-zero gradients from the engines, so
//! their Adam moments stay zero and they never move; the trainer still calls
//! [`crate::nn::RnnCell::enforce_mask`] after each update as hygiene.

pub mod adam;
pub mod sgd;

pub use adam::{Adam, AdamState};
pub use sgd::Sgd;

/// A first-order optimizer over a flat parameter vector.
pub trait Optimizer {
    /// Apply one update given gradients (same layout/length as params).
    fn update(&mut self, params: &mut [f32], grads: &[f32]);
    /// Reset internal state (moments, step count).
    fn reset(&mut self);
}
