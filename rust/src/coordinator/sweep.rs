//! The Fig.-3 sweep: {activity sparsity on/off} × {parameter sparsity ω} ×
//! {depth L} × {seeds}, fanned out over the in-tree worker pool (one OS
//! thread per run, bounded by available parallelism), aggregated to
//! mean ± stderr. The paper's grid is depth 1; the `layers` axis extends
//! it to stacked networks (`model.layers`).

use crate::config::{AlgorithmKind, CellKind, ExperimentConfig};
use crate::metrics::curve::Curve;
use crate::train::{build_dataset, Trainer};
use crate::util::math::{mean, mean_f64, stderr};
use crate::util::pool;

/// Grid specification for the sweep.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    /// Base configuration (iterations, batch size, task, model dims).
    pub base: ExperimentConfig,
    /// Parameter-sparsity levels ω (paper: 0, 0.5, 0.8, 0.9).
    pub param_sparsities: Vec<f32>,
    /// Activity-sparsity arms (paper: with = EGRU, without = gated-tanh).
    pub activity: Vec<bool>,
    /// Stack depths L (paper: [1]).
    pub layers: Vec<usize>,
    /// Seeds (paper: 5 runs).
    pub seeds: Vec<u64>,
    /// Max concurrent runs (0 = available parallelism).
    pub max_workers: usize,
    /// Pin one gradient engine across every arm instead of the per-arm
    /// default. Engines are interchangeable behind [`crate::rtrl::GradientEngine`]
    /// (same gradients for the exact family), so any engine can run any
    /// arm — this is how e.g. a full SnAp-1 or UORO sweep is launched.
    pub engine_override: Option<AlgorithmKind>,
}

impl SweepPlan {
    /// The paper's Fig.-3 grid over a base config.
    pub fn fig3(base: ExperimentConfig, seeds: usize) -> Self {
        SweepPlan {
            base,
            param_sparsities: vec![0.0, 0.5, 0.8, 0.9],
            activity: vec![true, false],
            layers: vec![1],
            seeds: (1..=seeds as u64).collect(),
            max_workers: 0,
            engine_override: None,
        }
    }

    /// Expand into concrete run configs.
    pub fn expand(&self) -> Vec<RunSpec> {
        let mut runs = Vec::new();
        for &activity in &self.activity {
            for &layers in &self.layers {
                // loud, like the config layer: a zero-depth arm is a plan
                // bug, never something to silently clamp
                assert!(layers >= 1, "SweepPlan.layers entries must be ≥ 1 (got 0)");
                for &omega in &self.param_sparsities {
                    for &seed in &self.seeds {
                        let mut cfg = self.base.clone();
                        cfg.model.param_sparsity = omega;
                        cfg.model.layers = layers;
                        cfg.model.cell =
                            if activity { CellKind::Egru } else { CellKind::GatedTanh };
                        // engine matched to the arm: exact either way, but op
                        // counts reflect what that arm's hardware would exploit
                        cfg.train.algorithm = self.engine_override.unwrap_or(if activity {
                            AlgorithmKind::RtrlBoth
                        } else {
                            AlgorithmKind::RtrlParam
                        });
                        cfg.seed = seed;
                        cfg.name = format!(
                            "spiral-{}-L{}-w{:02}-s{}",
                            if activity { "egru" } else { "tanh" },
                            layers,
                            (omega * 100.0) as u32,
                            seed
                        );
                        runs.push(RunSpec { activity, omega, layers, seed, cfg });
                    }
                }
            }
        }
        runs
    }
}

/// One expanded run.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub activity: bool,
    pub omega: f32,
    pub layers: usize,
    pub seed: u64,
    pub cfg: ExperimentConfig,
}

/// Result of one run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub activity: bool,
    pub omega: f32,
    pub layers: usize,
    pub seed: u64,
    pub curve: Curve,
    pub final_val_accuracy: f32,
    pub total_macs: u64,
    pub influence_macs: u64,
    pub state_memory_words: usize,
    pub wallclock_secs: f64,
}

/// All runs of a sweep.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub runs: Vec<RunRecord>,
}

/// Execute one run synchronously (used by workers and by unit tests).
pub fn run_one(spec: &RunSpec) -> RunRecord {
    let t0 = std::time::Instant::now();
    let mut data_rng = Trainer::data_rng(spec.cfg.seed);
    let (train, val) = build_dataset(&spec.cfg, &mut data_rng);
    let mut trainer = Trainer::new(spec.cfg.clone());
    let out = trainer.train(&train, &val);
    RunRecord {
        activity: spec.activity,
        omega: spec.omega,
        layers: spec.layers,
        seed: spec.seed,
        curve: out.curve,
        final_val_accuracy: out.final_val_accuracy,
        total_macs: out.ops.total_macs(),
        influence_macs: out.ops.macs_in(crate::metrics::Phase::InfluenceUpdate),
        state_memory_words: out.state_memory_words,
        wallclock_secs: t0.elapsed().as_secs_f64(),
    }
}

/// Run the full sweep on a bounded in-tree thread pool.
pub fn run_sweep(plan: &SweepPlan, progress: bool) -> SweepResult {
    let specs = plan.expand();
    let workers = pool::resolve_workers(plan.max_workers);
    let total = specs.len();
    let done = std::sync::atomic::AtomicUsize::new(0);
    let runs = pool::run_parallel(specs, workers, |_, spec| {
        let rec = run_one(&spec);
        let i = done.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1;
        if progress {
            eprintln!(
                "[sweep {}/{}] {} -> val_acc={:.3} macs={} ({:.1}s)",
                i, total, spec.cfg.name, rec.final_val_accuracy, rec.total_macs, rec.wallclock_secs
            );
        }
        rec
    });
    SweepResult { runs }
}

/// One aggregated point of an arm's mean curve.
#[derive(Debug, Clone)]
pub struct ArmPoint {
    pub iteration: u64,
    pub compute_adjusted_mean: f64,
    pub loss_mean: f32,
    pub loss_stderr: f32,
    pub val_accuracy_mean: f32,
    pub val_accuracy_stderr: f32,
    pub alpha_mean: f32,
    pub beta_mean: f32,
    pub influence_sparsity_mean: f32,
}

impl SweepResult {
    /// Arms present, sorted (activity desc, L asc, ω asc).
    pub fn arms(&self) -> Vec<(bool, f32, usize)> {
        let mut arms: Vec<(bool, f32, usize)> = Vec::new();
        for r in &self.runs {
            if !arms.iter().any(|&(a, w, l)| {
                a == r.activity && (w - r.omega).abs() < 1e-6 && l == r.layers
            }) {
                arms.push((r.activity, r.omega, r.layers));
            }
        }
        arms.sort_by(|a, b| {
            b.0.cmp(&a.0)
                .then(a.2.cmp(&b.2))
                .then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
        });
        arms
    }

    /// Mean ± stderr curve of one arm, point-wise over the shared logging
    /// grid (runs log at identical iterations by construction).
    pub fn aggregate(&self, activity: bool, omega: f32, layers: usize) -> Vec<ArmPoint> {
        let members: Vec<&RunRecord> = self
            .runs
            .iter()
            .filter(|r| {
                r.activity == activity && (r.omega - omega).abs() < 1e-6 && r.layers == layers
            })
            .collect();
        if members.is_empty() {
            return Vec::new();
        }
        let npts = members.iter().map(|r| r.curve.points.len()).min().unwrap_or(0);
        (0..npts)
            .map(|i| {
                let losses: Vec<f32> = members.iter().map(|r| r.curve.points[i].loss).collect();
                let vals: Vec<f32> = members
                    .iter()
                    .filter_map(|r| r.curve.points[i].val_accuracy)
                    .collect();
                ArmPoint {
                    iteration: members[0].curve.points[i].iteration,
                    compute_adjusted_mean: mean_f64(
                        members.iter().map(|r| r.curve.points[i].compute_adjusted),
                        members.len(),
                    ),
                    loss_mean: mean(&losses),
                    loss_stderr: stderr(&losses),
                    val_accuracy_mean: mean(&vals),
                    val_accuracy_stderr: stderr(&vals),
                    alpha_mean: mean(
                        &members.iter().map(|r| r.curve.points[i].alpha).collect::<Vec<_>>(),
                    ),
                    beta_mean: mean(
                        &members.iter().map(|r| r.curve.points[i].beta).collect::<Vec<_>>(),
                    ),
                    influence_sparsity_mean: mean(
                        &members
                            .iter()
                            .map(|r| r.curve.points[i].influence_sparsity)
                            .collect::<Vec<_>>(),
                    ),
                }
            })
            .collect()
    }

    /// Long-form CSV of every logged point of every run (Fig. 3 source data).
    pub fn to_long_csv(&self) -> String {
        let mut s = String::from(
            "activity,omega,layers,seed,iteration,compute_adjusted,loss,accuracy,val_accuracy,alpha,beta,influence_sparsity,influence_macs\n",
        );
        for r in &self.runs {
            for p in &r.curve.points {
                s.push_str(&format!(
                    "{},{},{},{},{},{:.6},{:.6},{:.4},{},{:.4},{:.4},{:.4},{}\n",
                    r.activity,
                    r.omega,
                    r.layers,
                    r.seed,
                    p.iteration,
                    p.compute_adjusted,
                    p.loss,
                    p.accuracy,
                    p.val_accuracy.map(|v| format!("{v:.4}")).unwrap_or_default(),
                    p.alpha,
                    p.beta,
                    p.influence_sparsity,
                    p.influence_macs,
                ));
            }
        }
        s
    }

    /// Aggregated CSV (one row per arm × logged iteration).
    pub fn to_summary_csv(&self) -> String {
        let mut s = String::from(
            "activity,omega,layers,iteration,compute_adjusted_mean,loss_mean,loss_stderr,val_acc_mean,val_acc_stderr,alpha_mean,beta_mean,influence_sparsity_mean\n",
        );
        for (activity, omega, layers) in self.arms() {
            for p in self.aggregate(activity, omega, layers) {
                s.push_str(&format!(
                    "{},{},{},{},{:.6},{:.6},{:.6},{:.4},{:.4},{:.4},{:.4},{:.4}\n",
                    activity,
                    omega,
                    layers,
                    p.iteration,
                    p.compute_adjusted_mean,
                    p.loss_mean,
                    p.loss_stderr,
                    p.val_accuracy_mean,
                    p.val_accuracy_stderr,
                    p.alpha_mean,
                    p.beta_mean,
                    p.influence_sparsity_mean,
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_plan() -> SweepPlan {
        let mut base = ExperimentConfig::default();
        base.task.num_sequences = 80;
        base.train.iterations = 6;
        base.train.batch_size = 4;
        base.train.log_every = 2;
        base.train.eval_every = 3;
        base.train.eval_sequences = 8;
        base.model.hidden = 6;
        SweepPlan {
            base,
            param_sparsities: vec![0.0, 0.8],
            activity: vec![true, false],
            layers: vec![1],
            seeds: vec![1, 2],
            max_workers: 2,
            engine_override: None,
        }
    }

    #[test]
    fn expand_covers_grid() {
        let plan = tiny_plan();
        let runs = plan.expand();
        assert_eq!(runs.len(), 2 * 2 * 2);
        // EGRU for activity arms, gated-tanh otherwise
        for r in &runs {
            if r.activity {
                assert_eq!(r.cfg.model.cell, CellKind::Egru);
                assert_eq!(r.cfg.train.algorithm, AlgorithmKind::RtrlBoth);
            } else {
                assert_eq!(r.cfg.model.cell, CellKind::GatedTanh);
                assert_eq!(r.cfg.train.algorithm, AlgorithmKind::RtrlParam);
            }
        }
    }

    /// The depth axis expands into per-depth configs and shows up in the
    /// arm keys and CSV columns.
    #[test]
    fn depth_axis_expands_and_aggregates() {
        let mut plan = tiny_plan();
        plan.layers = vec![1, 2];
        plan.activity = vec![true];
        plan.param_sparsities = vec![0.0];
        plan.seeds = vec![1];
        plan.base.train.iterations = 3;
        let runs = plan.expand();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].cfg.model.layers, 1);
        assert_eq!(runs[1].cfg.model.layers, 2);
        assert!(runs[1].cfg.name.contains("L2"));
        let result = run_sweep(&plan, false);
        assert_eq!(result.arms(), vec![(true, 0.0, 1), (true, 0.0, 2)]);
        assert!(!result.aggregate(true, 0.0, 2).is_empty());
        assert!(result.to_summary_csv().starts_with("activity,omega,layers,"));
        assert!(result.to_long_csv().starts_with("activity,omega,layers,"));
    }

    #[test]
    fn engine_override_pins_every_arm() {
        let mut plan = tiny_plan();
        plan.engine_override = Some(AlgorithmKind::Snap1);
        for r in plan.expand() {
            assert_eq!(r.cfg.train.algorithm, AlgorithmKind::Snap1);
        }
    }

    #[test]
    fn sweep_runs_and_aggregates() {
        let plan = tiny_plan();
        let result = run_sweep(&plan, false);
        assert_eq!(result.runs.len(), 8);
        assert_eq!(result.arms().len(), 4);
        let agg = result.aggregate(true, 0.0, 1);
        assert!(!agg.is_empty());
        let csv = result.to_summary_csv();
        assert!(csv.lines().count() > 4);
        let long = result.to_long_csv();
        assert!(long.lines().count() > 8);
    }
}
