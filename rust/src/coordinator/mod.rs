//! Sweep coordinator: runs the Fig.-3 experiment grid across async workers.

pub mod sweep;

pub use sweep::{run_sweep, SweepPlan, SweepResult};
