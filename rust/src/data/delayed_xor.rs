//! Delayed-XOR task: two ±1 pulses arrive at random times on one channel;
//! at the end the network must output the XOR of their signs. Tests
//! multiplicative temporal interactions (a single pulse carries no signal).

use super::{Dataset, Sequence, StepTarget};
use crate::util::Pcg64;

#[derive(Debug, Clone)]
pub struct DelayedXorConfig {
    pub num_sequences: usize,
    pub timesteps: usize,
}

impl Default for DelayedXorConfig {
    fn default() -> Self {
        DelayedXorConfig { num_sequences: 2000, timesteps: 12 }
    }
}

/// Generate the delayed-XOR dataset (input channels `[pulse, end_marker]`).
pub fn generate(cfg: &DelayedXorConfig, rng: &mut Pcg64) -> Dataset {
    assert!(cfg.timesteps >= 4);
    let mut seqs = Vec::with_capacity(cfg.num_sequences);
    for _ in 0..cfg.num_sequences {
        let t1 = rng.below((cfg.timesteps / 2) as u64) as usize;
        let t2 = cfg.timesteps / 2 + rng.below((cfg.timesteps / 2 - 1) as u64) as usize;
        let b1 = rng.below(2) == 1;
        let b2 = rng.below(2) == 1;
        let class = (b1 ^ b2) as usize;
        let mut inputs = vec![vec![0.0f32; 2]; cfg.timesteps];
        let mut targets = vec![StepTarget::None; cfg.timesteps];
        inputs[t1][0] = if b1 { 1.0 } else { -1.0 };
        inputs[t2][0] = if b2 { 1.0 } else { -1.0 };
        inputs[cfg.timesteps - 1][1] = 1.0;
        targets[cfg.timesteps - 1] = StepTarget::Class(class);
        seqs.push(Sequence { inputs, targets });
    }
    Dataset { seqs, n_in: 2, n_out: 2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_pulses_and_final_target() {
        let cfg = DelayedXorConfig { num_sequences: 20, timesteps: 12 };
        let mut rng = Pcg64::new(1);
        let d = generate(&cfg, &mut rng);
        for s in &d.seqs {
            let pulses = s.inputs.iter().filter(|x| x[0] != 0.0).count();
            assert_eq!(pulses, 2);
            assert!(matches!(s.targets[11], StepTarget::Class(_)));
            // label equals xor of pulse signs
            let signs: Vec<bool> =
                s.inputs.iter().filter(|x| x[0] != 0.0).map(|x| x[0] > 0.0).collect();
            assert_eq!(s.label().unwrap(), (signs[0] ^ signs[1]) as usize);
        }
    }

    #[test]
    fn pulses_in_separate_halves() {
        let cfg = DelayedXorConfig { num_sequences: 50, timesteps: 16 };
        let mut rng = Pcg64::new(2);
        let d = generate(&cfg, &mut rng);
        for s in &d.seqs {
            let times: Vec<usize> =
                (0..16).filter(|&t| s.inputs[t][0] != 0.0).collect();
            assert!(times[0] < 8 && times[1] >= 8);
        }
    }
}
