//! The paper's synthetic task (§6): "a two-dimensional spiral unwinding over
//! time is classified as clockwise or anti-clockwise. The dataset consisted
//! of 10,000 randomly generated spirals of 17 timesteps length assigned to
//! one of the two classes depending on the orientation of the spiral."
//!
//! Each sequence presents the spiral's 2-D coordinates step by step; the
//! class target sits on the final step (sequence classification). Random
//! initial phase, radius and angular velocity jitter make the task
//! non-trivial while keeping orientation the only class signal.

use super::{Dataset, Sequence, StepTarget};
use crate::util::Pcg64;

/// Generator parameters for the spiral dataset.
#[derive(Debug, Clone)]
pub struct SpiralConfig {
    /// Number of sequences (paper: 10 000).
    pub num_sequences: usize,
    /// Sequence length (paper: 17).
    pub timesteps: usize,
    /// Base angular step per timestep (radians).
    pub angular_velocity: f32,
    /// Radius growth per timestep (the "unwinding").
    pub radial_velocity: f32,
    /// Gaussian coordinate noise.
    pub noise: f32,
}

impl Default for SpiralConfig {
    fn default() -> Self {
        SpiralConfig {
            num_sequences: 10_000,
            timesteps: 17,
            angular_velocity: 0.4,
            radial_velocity: 0.08,
            noise: 0.02,
        }
    }
}

/// The spiral classification dataset.
pub struct SpiralDataset;

impl SpiralDataset {
    /// Generate the dataset. Class 0 = clockwise (θ decreasing),
    /// class 1 = anti-clockwise (θ increasing). Balanced by construction.
    pub fn generate(cfg: &SpiralConfig, rng: &mut Pcg64) -> Dataset {
        let mut seqs = Vec::with_capacity(cfg.num_sequences);
        for i in 0..cfg.num_sequences {
            let class = i % 2;
            seqs.push(Self::one_spiral(cfg, class, rng));
        }
        rng.shuffle(&mut seqs);
        Dataset { seqs, n_in: 2, n_out: 2 }
    }

    fn one_spiral(cfg: &SpiralConfig, class: usize, rng: &mut Pcg64) -> Sequence {
        let phase = rng.uniform(0.0, 2.0 * std::f32::consts::PI);
        let r0 = rng.uniform(0.1, 0.3);
        // jittered speeds so classes are not separable by radius alone
        let omega = cfg.angular_velocity * rng.uniform(0.8, 1.2);
        let rho = cfg.radial_velocity * rng.uniform(0.8, 1.2);
        let sign = if class == 1 { 1.0 } else { -1.0 };
        let mut inputs = Vec::with_capacity(cfg.timesteps);
        let mut targets = Vec::with_capacity(cfg.timesteps);
        for t in 0..cfg.timesteps {
            let theta = phase + sign * omega * t as f32;
            let r = r0 + rho * t as f32;
            let x = r * theta.cos() + cfg.noise * rng.normal();
            let y = r * theta.sin() + cfg.noise * rng.normal();
            inputs.push(vec![x, y]);
            targets.push(if t + 1 == cfg.timesteps {
                StepTarget::Class(class)
            } else {
                StepTarget::None
            });
        }
        Sequence { inputs, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SpiralConfig {
        SpiralConfig { num_sequences: 100, ..Default::default() }
    }

    #[test]
    fn shapes_match_paper() {
        let mut rng = Pcg64::new(1);
        let d = SpiralDataset::generate(&small_cfg(), &mut rng);
        assert_eq!(d.len(), 100);
        assert_eq!(d.n_in, 2);
        assert_eq!(d.n_out, 2);
        for s in &d.seqs {
            assert_eq!(s.len(), 17);
            assert_eq!(s.inputs[0].len(), 2);
            // only final step supervised
            assert!(s.targets[..16].iter().all(|t| *t == StepTarget::None));
            assert!(matches!(s.targets[16], StepTarget::Class(_)));
        }
    }

    #[test]
    fn classes_balanced() {
        let mut rng = Pcg64::new(2);
        let d = SpiralDataset::generate(&small_cfg(), &mut rng);
        let ones = d.seqs.iter().filter(|s| s.label() == Some(1)).count();
        assert_eq!(ones, 50);
    }

    #[test]
    fn orientation_differs_by_class() {
        // cross product of consecutive displacement vectors has the sign of
        // the turning direction; verify it separates the classes
        let mut rng = Pcg64::new(3);
        let d = SpiralDataset::generate(&small_cfg(), &mut rng);
        for s in &d.seqs {
            let mut cross_sum = 0.0f32;
            for t in 1..s.len() - 1 {
                let (ax, ay) = (
                    s.inputs[t][0] - s.inputs[t - 1][0],
                    s.inputs[t][1] - s.inputs[t - 1][1],
                );
                let (bx, by) = (
                    s.inputs[t + 1][0] - s.inputs[t][0],
                    s.inputs[t + 1][1] - s.inputs[t][1],
                );
                cross_sum += ax * by - ay * bx;
            }
            let predicted = if cross_sum > 0.0 { 1 } else { 0 };
            assert_eq!(Some(predicted), s.label(), "orientation signal broken");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SpiralDataset::generate(&small_cfg(), &mut Pcg64::new(9));
        let b = SpiralDataset::generate(&small_cfg(), &mut Pcg64::new(9));
        assert_eq!(a.seqs[0].inputs, b.seqs[0].inputs);
    }
}
