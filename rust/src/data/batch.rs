//! Shuffled minibatch iteration over a [`Dataset`](super::Dataset).

use super::Dataset;
use crate::util::Pcg64;

/// Epoch-less minibatch sampler: reshuffles indices whenever exhausted, so
/// "iteration" counts parameter updates as in the paper (1700 iterations ≫
/// one epoch of 10 000/32 batches).
pub struct BatchIter {
    order: Vec<usize>,
    cursor: usize,
    batch_size: usize,
    rng: Pcg64,
}

impl BatchIter {
    pub fn new(dataset_len: usize, batch_size: usize, seed: u64) -> Self {
        assert!(batch_size >= 1);
        assert!(dataset_len >= 1);
        let mut it = BatchIter {
            order: (0..dataset_len).collect(),
            cursor: 0,
            batch_size,
            rng: Pcg64::new(seed),
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Indices of the next minibatch (always `batch_size` long; reshuffles
    /// and wraps at the dataset boundary).
    pub fn next_batch(&mut self) -> Vec<usize> {
        let mut batch = Vec::with_capacity(self.batch_size);
        while batch.len() < self.batch_size {
            if self.cursor >= self.order.len() {
                self.reshuffle();
            }
            batch.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        batch
    }

    /// Borrow sequences for a batch from a dataset.
    pub fn gather<'d>(dataset: &'d Dataset, idx: &[usize]) -> Vec<&'d super::Sequence> {
        idx.iter().map(|&i| &dataset.seqs[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_right_size_and_range() {
        let mut it = BatchIter::new(10, 3, 1);
        for _ in 0..20 {
            let b = it.next_batch();
            assert_eq!(b.len(), 3);
            assert!(b.iter().all(|&i| i < 10));
        }
    }

    #[test]
    fn covers_all_indices_within_two_epochs() {
        let mut it = BatchIter::new(7, 2, 2);
        let mut seen = vec![false; 7];
        for _ in 0..7 {
            for i in it.next_batch() {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = BatchIter::new(20, 4, 3);
        let mut b = BatchIter::new(20, 4, 3);
        for _ in 0..10 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }
}
