//! Infinite stream for online learning: temporal parity.
//!
//! At each step a random bit arrives; the target is the parity of the last
//! `window` bits. There are no sequence boundaries — exactly the setting
//! RTRL exists for (BPTT would need to truncate). Used by the
//! `online_learning` example and the coordinator's streaming server.

use super::StepTarget;
use crate::util::Pcg64;

/// Stateful generator of `(input, target)` stream steps.
#[derive(Debug, Clone)]
pub struct ParityStream {
    window: usize,
    history: Vec<bool>,
    rng: Pcg64,
    /// Steps emitted so far.
    pub steps: u64,
}

impl ParityStream {
    pub fn new(window: usize, seed: u64) -> Self {
        assert!(window >= 1);
        ParityStream { window, history: Vec::new(), rng: Pcg64::new(seed), steps: 0 }
    }

    pub fn n_in(&self) -> usize {
        1
    }

    pub fn n_out(&self) -> usize {
        2
    }

    /// Next stream element. Target is `None` until the window has filled.
    pub fn next_step(&mut self) -> (Vec<f32>, StepTarget) {
        let bit = self.rng.below(2) == 1;
        self.history.push(bit);
        if self.history.len() > self.window {
            self.history.remove(0);
        }
        self.steps += 1;
        let x = vec![if bit { 1.0 } else { -1.0 }];
        let target = if self.history.len() == self.window {
            let parity = self.history.iter().filter(|&&b| b).count() % 2;
            StepTarget::Class(parity)
        } else {
            StepTarget::None
        };
        (x, target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_supervised() {
        let mut s = ParityStream::new(3, 1);
        let (_, t0) = s.next_step();
        let (_, t1) = s.next_step();
        assert_eq!(t0, StepTarget::None);
        assert_eq!(t1, StepTarget::None);
        let (_, t2) = s.next_step();
        assert!(matches!(t2, StepTarget::Class(_)));
    }

    #[test]
    fn parity_is_correct() {
        let mut s = ParityStream::new(2, 7);
        let mut last_bits = Vec::new();
        for _ in 0..100 {
            let (x, t) = s.next_step();
            last_bits.push(x[0] > 0.0);
            if last_bits.len() > 2 {
                last_bits.remove(0);
            }
            if let StepTarget::Class(c) = t {
                let expect = last_bits.iter().filter(|&&b| b).count() % 2;
                assert_eq!(c, expect);
            }
        }
    }

    #[test]
    fn deterministic() {
        let collect = |seed| {
            let mut s = ParityStream::new(3, seed);
            (0..20).map(|_| s.next_step().0[0]).collect::<Vec<_>>()
        };
        assert_eq!(collect(5), collect(5));
        assert_ne!(collect(5), collect(6));
    }
}
