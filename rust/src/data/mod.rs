//! Datasets: the paper's spiral task plus standard temporal-credit
//! benchmarks and an infinite stream for online learning.

pub mod batch;
pub mod copy_task;
pub mod delayed_xor;
pub mod spiral;
pub mod stream;

pub use batch::BatchIter;
pub use spiral::SpiralDataset;

use crate::rtrl::Target;

/// Owned per-step supervision.
#[derive(Debug, Clone, PartialEq)]
pub enum StepTarget {
    None,
    Class(usize),
    Vector(Vec<f32>),
}

impl StepTarget {
    /// Borrowed view for the engines.
    pub fn as_target(&self) -> Target<'_> {
        match self {
            StepTarget::None => Target::None,
            StepTarget::Class(c) => Target::Class(*c),
            StepTarget::Vector(v) => Target::Vector(v),
        }
    }
}

/// One labelled sequence.
#[derive(Debug, Clone)]
pub struct Sequence {
    /// `inputs[t]` is the `n_in`-dimensional input at step `t`.
    pub inputs: Vec<Vec<f32>>,
    /// `targets[t]` is the supervision at step `t` (often only final step).
    pub targets: Vec<StepTarget>,
}

impl Sequence {
    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// Class label of the last supervised step, if classification.
    pub fn label(&self) -> Option<usize> {
        self.targets.iter().rev().find_map(|t| match t {
            StepTarget::Class(c) => Some(*c),
            _ => None,
        })
    }
}

/// A dataset of sequences with fixed input/output dimensionality.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub seqs: Vec<Sequence>,
    pub n_in: usize,
    pub n_out: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Split off the last `frac` of sequences as a validation set.
    pub fn split_validation(mut self, frac: f32) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&frac));
        let n_val = ((self.seqs.len() as f32) * frac).round() as usize;
        let val_seqs = self.seqs.split_off(self.seqs.len() - n_val);
        let val = Dataset { seqs: val_seqs, n_in: self.n_in, n_out: self.n_out };
        (self, val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(label: usize) -> Sequence {
        Sequence {
            inputs: vec![vec![0.0, 0.0]; 3],
            targets: vec![StepTarget::None, StepTarget::None, StepTarget::Class(label)],
        }
    }

    #[test]
    fn label_finds_last_class() {
        assert_eq!(seq(1).label(), Some(1));
    }

    #[test]
    fn split_validation_sizes() {
        let d = Dataset { seqs: (0..100).map(|i| seq(i % 2)).collect(), n_in: 2, n_out: 2 };
        let (train, val) = d.split_validation(0.2);
        assert_eq!(train.len(), 80);
        assert_eq!(val.len(), 20);
    }

    #[test]
    fn step_target_borrows() {
        let t = StepTarget::Class(3);
        assert!(matches!(t.as_target(), Target::Class(3)));
        let v = StepTarget::Vector(vec![1.0]);
        assert!(matches!(v.as_target(), Target::Vector(_)));
    }
}
