//! Copy-memory task: remember `k` random bits across a delay and reproduce
//! them on cue — the classic long-range-credit benchmark for online
//! learning algorithms (used by Menick et al. 2020 for SnAp).
//!
//! Input channels: `[bit, recall_cue]`. During presentation the bit channel
//! carries the payload; after the delay the cue channel goes high for `k`
//! steps and the network must classify the stored bits in order.

use super::{Dataset, Sequence, StepTarget};
use crate::util::Pcg64;

#[derive(Debug, Clone)]
pub struct CopyConfig {
    pub num_sequences: usize,
    /// Payload length in bits.
    pub payload: usize,
    /// Silent delay between presentation and recall.
    pub delay: usize,
}

impl Default for CopyConfig {
    fn default() -> Self {
        CopyConfig { num_sequences: 2000, payload: 3, delay: 5 }
    }
}

/// Generate the copy-memory dataset. Targets are per-step classes (bit
/// values) during the recall window.
pub fn generate(cfg: &CopyConfig, rng: &mut Pcg64) -> Dataset {
    let t_total = cfg.payload + cfg.delay + cfg.payload;
    let mut seqs = Vec::with_capacity(cfg.num_sequences);
    for _ in 0..cfg.num_sequences {
        let bits: Vec<usize> = (0..cfg.payload).map(|_| rng.below(2) as usize).collect();
        let mut inputs = vec![vec![0.0f32; 2]; t_total];
        let mut targets = vec![StepTarget::None; t_total];
        for (i, &b) in bits.iter().enumerate() {
            inputs[i][0] = if b == 1 { 1.0 } else { -1.0 };
        }
        for i in 0..cfg.payload {
            let t = cfg.payload + cfg.delay + i;
            inputs[t][1] = 1.0; // recall cue
            targets[t] = StepTarget::Class(bits[i]);
        }
        seqs.push(Sequence { inputs, targets });
    }
    Dataset { seqs, n_in: 2, n_out: 2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let cfg = CopyConfig { num_sequences: 10, payload: 3, delay: 5 };
        let mut rng = Pcg64::new(1);
        let d = generate(&cfg, &mut rng);
        assert_eq!(d.len(), 10);
        for s in &d.seqs {
            assert_eq!(s.len(), 11);
            // exactly `payload` supervised steps, all in recall window
            let supervised: Vec<usize> = (0..s.len())
                .filter(|&t| s.targets[t] != StepTarget::None)
                .collect();
            assert_eq!(supervised, vec![8, 9, 10]);
            // cue channel high only during recall
            for t in 0..s.len() {
                assert_eq!(s.inputs[t][1] == 1.0, t >= 8);
            }
        }
    }

    #[test]
    fn targets_match_payload() {
        let cfg = CopyConfig { num_sequences: 50, payload: 2, delay: 3 };
        let mut rng = Pcg64::new(2);
        let d = generate(&cfg, &mut rng);
        for s in &d.seqs {
            for i in 0..2 {
                let presented = s.inputs[i][0] > 0.0;
                let t = 2 + 3 + i;
                match &s.targets[t] {
                    StepTarget::Class(c) => assert_eq!(*c == 1, presented),
                    // the generator places a class target on every recall
                    // step by construction — anything else is a generator bug
                    other => unreachable!(
                        "recall step {t} (payload bit {i}) lost its class target: {other:?}"
                    ),
                }
            }
        }
    }

    /// Regression over target *placement*: for arbitrary payload/delay
    /// geometry, supervision covers exactly the recall window
    /// `[payload+delay, payload+delay+payload)` — never the presentation or
    /// delay phases — and each recall step carries a `Class` target.
    #[test]
    fn targets_cover_exactly_the_recall_window() {
        for (payload, delay) in [(1usize, 0usize), (2, 1), (3, 5), (4, 7)] {
            let cfg = CopyConfig { num_sequences: 5, payload, delay };
            let mut rng = Pcg64::new(7 + payload as u64);
            let d = generate(&cfg, &mut rng);
            let t_total = 2 * payload + delay;
            for s in &d.seqs {
                assert_eq!(s.len(), t_total);
                for t in 0..t_total {
                    let in_recall = t >= payload + delay;
                    match (&s.targets[t], in_recall) {
                        (StepTarget::Class(c), true) => assert!(*c < 2),
                        (StepTarget::None, false) => {}
                        (other, _) => unreachable!(
                            "payload={payload} delay={delay}: step {t} has {other:?} \
                             (in_recall={in_recall})"
                        ),
                    }
                }
            }
        }
    }
}
