//! Factories: config → stack / engine / dataset.

use crate::config::{AlgorithmKind, CellKind, ExperimentConfig, TaskKind};
use crate::data::{copy_task, delayed_xor, spiral, Dataset};
use crate::nn::{LayerStack, RnnCell};
use crate::rtrl::{Bptt, DenseRtrl, GradientEngine, Snap1, Snap2, SparseRtrl, SparsityMode, Uoro};
use crate::sparse::MaskPattern;
use crate::util::Pcg64;

/// Build one recurrent cell (mask drawn first so the pattern is independent
/// of weight-init draws, as in "fixed random sparsity mask at
/// initialisation").
fn build_cell_with(cfg: &ExperimentConfig, n_in: usize, rng: &mut Pcg64) -> RnnCell {
    let m = &cfg.model;
    let n = m.hidden;
    let mask = if m.param_sparsity > 0.0 {
        Some(MaskPattern::random(n, n, 1.0 - m.param_sparsity, rng))
    } else {
        None
    };
    match m.cell {
        CellKind::Egru => RnnCell::egru(n, n_in, m.theta, m.gamma, m.eps, mask, rng),
        CellKind::EvRnn => RnnCell::evrnn(n, n_in, m.theta, m.gamma, m.eps, mask, rng),
        CellKind::GatedTanh => RnnCell::gated_tanh(n, n_in, mask, rng),
        CellKind::Vanilla => RnnCell::vanilla(n, n_in, mask, rng),
    }
}

/// Build the full layer stack: layer 0 reads the task input, every deeper
/// layer reads the previous layer's `hidden` activations. Each layer draws
/// its own mask at the configured sparsity (independent patterns, as in
/// per-layer fixed random masks).
pub fn build_stack(cfg: &ExperimentConfig, rng: &mut Pcg64) -> LayerStack {
    assert!(cfg.model.layers >= 1, "model.layers must be ≥ 1");
    let mut cells = Vec::with_capacity(cfg.model.layers);
    for l in 0..cfg.model.layers {
        let n_in = if l == 0 { task_n_in(cfg) } else { cfg.model.hidden };
        cells.push(build_cell_with(cfg, n_in, rng));
    }
    LayerStack::new(cells)
}

/// Input dimensionality implied by the task.
pub fn task_n_in(cfg: &ExperimentConfig) -> usize {
    match cfg.task.task {
        TaskKind::Spiral => 2,
        TaskKind::Copy => 2,
        TaskKind::DelayedXor => 2,
    }
}

/// Output classes implied by the task.
pub fn task_n_out(_cfg: &ExperimentConfig) -> usize {
    2 // all bundled tasks are binary classification
}

/// Build the gradient engine for a stack.
pub fn build_engine(kind: AlgorithmKind, net: &LayerStack, n_out: usize) -> Box<dyn GradientEngine> {
    match kind {
        AlgorithmKind::RtrlDense => Box::new(DenseRtrl::new(net, n_out)),
        AlgorithmKind::RtrlActivity => Box::new(SparseRtrl::new(net, n_out, SparsityMode::Activity)),
        AlgorithmKind::RtrlParam => Box::new(SparseRtrl::new(net, n_out, SparsityMode::Parameter)),
        AlgorithmKind::RtrlBoth => Box::new(SparseRtrl::new(net, n_out, SparsityMode::Both)),
        AlgorithmKind::Snap1 => Box::new(Snap1::new(net, n_out)),
        AlgorithmKind::Snap2 => Box::new(Snap2::new(net, n_out)),
        // fixed stream seed: the trainer's gradient stochasticity is UORO's
        // own; reproducibility comes from the experiment seed path
        AlgorithmKind::Uoro => Box::new(Uoro::new(net, n_out, 0x706f_726f)),
        AlgorithmKind::Bptt => Box::new(Bptt::new(net, n_out)),
    }
}

/// Generate train + validation datasets for the configured task.
pub fn build_dataset(cfg: &ExperimentConfig, rng: &mut Pcg64) -> (Dataset, Dataset) {
    let full = match cfg.task.task {
        TaskKind::Spiral => spiral::SpiralDataset::generate(
            &spiral::SpiralConfig {
                num_sequences: cfg.task.num_sequences,
                timesteps: cfg.task.timesteps,
                ..Default::default()
            },
            rng,
        ),
        TaskKind::Copy => copy_task::generate(
            &copy_task::CopyConfig {
                num_sequences: cfg.task.num_sequences,
                ..Default::default()
            },
            rng,
        ),
        TaskKind::DelayedXor => delayed_xor::generate(
            &delayed_xor::DelayedXorConfig {
                num_sequences: cfg.task.num_sequences,
                timesteps: cfg.task.timesteps,
            },
            rng,
        ),
    };
    full.split_validation(cfg.task.val_fraction)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_engine() {
        let cfg = ExperimentConfig::default();
        let mut rng = Pcg64::new(1);
        let net = build_stack(&cfg, &mut rng);
        for kind in AlgorithmKind::all() {
            let eng = build_engine(kind, &net, 2);
            assert_eq!(eng.name(), kind.name());
        }
    }

    #[test]
    fn masked_stack_when_sparsity_positive() {
        let mut cfg = ExperimentConfig::default();
        cfg.model.param_sparsity = 0.8;
        let mut rng = Pcg64::new(2);
        let net = build_stack(&cfg, &mut rng);
        assert!((net.omega_tilde() - 0.2).abs() < 0.01);
    }

    #[test]
    fn multi_layer_stack_wires_hidden_to_hidden() {
        let mut cfg = ExperimentConfig::default();
        cfg.model.layers = 3;
        cfg.model.hidden = 12;
        cfg.model.param_sparsity = 0.5;
        let mut rng = Pcg64::new(3);
        let net = build_stack(&cfg, &mut rng);
        assert_eq!(net.layers(), 3);
        assert_eq!(net.layer(0).n_in(), task_n_in(&cfg));
        assert_eq!(net.layer(1).n_in(), 12);
        assert_eq!(net.layer(2).n_in(), 12);
        assert_eq!(net.total_units(), 36);
        // each layer draws an independent mask
        let m0 = net.layer(0).mask().unwrap();
        let m1 = net.layer(1).mask().unwrap();
        let differs = (0..12)
            .flat_map(|r| (0..12).map(move |c| (r, c)))
            .any(|(r, c)| m0.is_kept(r, c) != m1.is_kept(r, c));
        assert!(differs, "layer masks should be independent draws");
    }

    #[test]
    fn dataset_split() {
        let mut cfg = ExperimentConfig::default();
        cfg.task.num_sequences = 100;
        let mut rng = Pcg64::new(3);
        let (train, val) = build_dataset(&cfg, &mut rng);
        assert_eq!(train.len() + val.len(), 100);
        assert_eq!(val.len(), 10);
    }
}
