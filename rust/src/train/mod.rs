//! Training orchestration: config → components → training loop → curve.

pub mod build;
pub mod trainer;

pub use build::{build_cell, build_dataset, build_engine};
pub use trainer::{TrainOutcome, Trainer};
