//! Training orchestration: config → components → training loop → curve.

pub mod build;
pub mod trainer;

pub use build::{build_dataset, build_engine, build_stack};
pub use trainer::{TrainOutcome, Trainer};
