//! The training loop (paper §6 protocol): minibatch RTRL/BPTT with Adam,
//! per-iteration sparsity + compute accounting, periodic validation —
//! over a [`LayerStack`] of any depth.
//!
//! Since the session redesign the trainer is a **thin client** of
//! [`OnlineSession`]: it owns the dataset loop, minibatch averaging and the
//! rewiring schedule, while the session owns every learning component
//! (stack, readout, engine, optimizers, op counters). The trainer drives
//! the session under [`UpdatePolicy::Manual`] — `begin_sequence` → `step`×T
//! → `end_sequence` per sequence, then one [`OnlineSession::apply_update`]
//! scaled by `1/batch_size` per iteration — which reproduces the historical
//! trainer semantics exactly (same RNG stream order, same op accounting,
//! same gradient math).

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::metrics::curve::{Curve, CurvePoint};
use crate::metrics::{ComputeAdjusted, OpCounter, Phase, SparsityStats};
use crate::nn::{LayerStack, Loss, Readout};
use crate::session::{OnlineSession, SessionBuilder, UpdatePolicy};
use crate::util::Pcg64;

/// Everything a finished run reports.
pub struct TrainOutcome {
    pub curve: Curve,
    /// Total MACs spent, by phase (and by layer where attributable).
    pub ops: OpCounter,
    /// Final validation accuracy.
    pub final_val_accuracy: f32,
    /// Engine state memory (words) — the Table-1 memory column.
    pub state_memory_words: usize,
}

/// Single-run trainer: dataset loop + minibatch schedule over an
/// [`OnlineSession`].
pub struct Trainer {
    /// The learning state, which also owns the experiment config
    /// ([`Trainer::config`]). Public so callers can inspect the stack,
    /// readout or engine mid-training (tests, reports).
    pub session: OnlineSession,
    batch_rng: Pcg64,
}

impl Trainer {
    /// Build a trainer from a config. RNG streams are split per component so
    /// e.g. two algorithms see identical weight init and data order (the
    /// split order lives in [`SessionBuilder::build`]).
    pub fn new(cfg: ExperimentConfig) -> Self {
        let mut root = Pcg64::new(cfg.seed);
        let _cell_rng = root.split();
        let _readout_rng = root.split();
        let _data_rng = root.split(); // consumed by callers building datasets
        let batch_rng = root.split();
        let session = SessionBuilder::from_config(cfg).policy(UpdatePolicy::Manual).build();
        Trainer { session, batch_rng }
    }

    /// The experiment configuration (owned by the session — a single copy,
    /// so there is no second config that could silently diverge).
    pub fn config(&self) -> &ExperimentConfig {
        self.session.config()
    }

    /// Worker threads for the engine's intra-step kernels (`0` = available
    /// hardware parallelism). Training results are bit-identical at any
    /// value — this trades nothing but wall-clock.
    pub fn set_threads(&mut self, threads: usize) {
        self.session.set_threads(threads);
    }

    /// Dataset RNG matching the stream order used by [`Trainer::new`].
    pub fn data_rng(seed: u64) -> Pcg64 {
        let mut root = Pcg64::new(seed);
        let _ = root.split();
        let _ = root.split();
        root.split()
    }

    /// The recurrent stack under training.
    pub fn net(&self) -> &LayerStack {
        self.session.net()
    }

    /// The readout under training.
    pub fn readout(&self) -> &Readout {
        self.session.readout()
    }

    /// Run one gradient sequence through the session and harvest its
    /// gradient into the batch accumulator (manual policy: no update yet).
    /// Returns (mean step loss, final correct).
    fn run_sequence(
        &mut self,
        seq: &crate::data::Sequence,
        stats: &mut SparsityStats,
        measure_influence: bool,
    ) -> (f32, bool) {
        self.session.set_measure_influence(measure_influence);
        self.session.begin_sequence();
        let mut loss_sum = 0.0;
        let mut loss_count = 0u32;
        let mut last_correct = false;
        let n_total = self.session.net().total_units();
        for (t, x) in seq.inputs.iter().enumerate() {
            let r = self.session.step(x, seq.targets[t].as_target());
            stats.record_step(n_total, r.active_units, r.deriv_units);
            if let Some(l) = r.loss {
                loss_sum += l;
                loss_count += 1;
            }
            if let Some(c) = r.correct {
                last_correct = c;
            }
            if let Some(s) = r.influence_sparsity {
                stats.record_influence(s);
            }
        }
        self.session.end_sequence();
        (loss_sum / loss_count.max(1) as f32, last_correct)
    }

    /// One Deep-Rewiring-style step (paper Discussion / Bellec et al. 2018),
    /// applied to every masked layer: relocate the lowest-magnitude kept
    /// recurrent connections, rebuild the engine (its column maps track the
    /// new patterns) and reset the Adam moments of every swapped parameter
    /// (indices in the concatenated layout).
    fn rewire(&mut self, rng: &mut Pcg64) {
        let rewire_fraction = self.session.config().train.rewire_fraction;
        let mut swapped = Vec::new();
        let mut any = false;
        let net = self.session.net_mut();
        for l in 0..net.layers() {
            if net.layer(l).mask().is_none() {
                continue;
            }
            any = true;
            let old_mask = net.layer(l).mask().unwrap().clone();
            let new_mask =
                crate::sparse::rewire::magnitude_rewire(net.layer(l), rewire_fraction, rng);
            // flat indices of swapped recurrent params (either direction),
            // offset into the concatenated parameter space
            let n = net.layer(l).n();
            let poff = net.layout().param_offset(l);
            let layout = net.layer(l).layout().clone();
            for &b in &net.layer(l).recurrent_blocks() {
                for r in 0..n {
                    for c in 0..n {
                        if old_mask.is_kept(r, c) != new_mask.is_kept(r, c) {
                            swapped.push(poff + layout.flat(b, r, c));
                        }
                    }
                }
            }
            // grow at ~10% of the fresh-init scale so new connections start small
            let grow = 0.1 * (6.0 / (2 * n) as f32).sqrt() / new_mask.density().sqrt();
            net.layer_mut(l).set_mask(new_mask, grow, rng);
        }
        if !any {
            return;
        }
        self.session.optimizer_cell_mut().reset_indices(&swapped);
        self.session.rebuild_engine();
    }

    /// Forward-only accuracy over (a subsample of) a dataset.
    pub fn evaluate(&self, data: &Dataset, max_sequences: usize) -> f32 {
        let net = self.session.net();
        let readout = self.session.readout();
        let mut scratch = net.scratch();
        let mut logits = vec![0.0; readout.n_out()];
        let mut discard = OpCounter::new();
        let take = data.len().min(max_sequences.max(1));
        let mut correct = 0usize;
        let mut total = 0usize;
        for seq in data.seqs.iter().take(take) {
            let mut a_prev = vec![0.0; net.total_units()];
            for (t, x) in seq.inputs.iter().enumerate() {
                net.forward(&a_prev, x, &mut scratch, &mut discard);
                if let crate::data::StepTarget::Class(c) = &seq.targets[t] {
                    readout.forward(&scratch.top().a, &mut logits, &mut discard);
                    total += 1;
                    if Loss::predict(&logits) == *c {
                        correct += 1;
                    }
                }
                scratch.write_state(&mut a_prev);
            }
        }
        if total == 0 {
            0.0
        } else {
            correct as f32 / total as f32
        }
    }

    /// Full training loop per the config. Returns curve + cost accounting.
    pub fn train(&mut self, train_data: &Dataset, val_data: &Dataset) -> TrainOutcome {
        let cfg = self.session.config();
        let iters = cfg.train.iterations;
        let batch_size = cfg.train.batch_size;
        let log_every = cfg.train.log_every.max(1);
        let eval_every = cfg.train.eval_every;
        let eval_sequences = cfg.train.eval_sequences;
        let rewire_every = cfg.train.rewire_every;
        let seed = cfg.seed;
        let activity_sparse = cfg.model.cell.is_event_based();
        let mut compute = ComputeAdjusted::new(cfg.omega_tilde(), activity_sparse);
        let mut batches = crate::data::BatchIter::new(
            train_data.len(),
            batch_size,
            self.batch_rng.next_u64(),
        );
        let mut curve = Curve::new();
        for it in 0..iters {
            let logging = it % log_every == 0 || it + 1 == iters;
            let mut stats = SparsityStats::new();
            let ops_before = self.session.ops.clone();
            let idx = batches.next_batch();
            let mut loss_sum = 0.0;
            let mut correct = 0usize;
            for (bi, &si) in idx.iter().enumerate() {
                // influence scan only on the first sequence of a logging iter
                let seq = &train_data.seqs[si];
                let (l, c) = self.run_sequence(seq, &mut stats, logging && bi == 0);
                loss_sum += l;
                if c {
                    correct += 1;
                }
            }
            self.session.apply_update(1.0 / batch_size as f32);
            if rewire_every > 0 && it > 0 && it % rewire_every == 0 {
                let mut rng = Pcg64::new(seed ^ (0x5e71_4e00 + it));
                self.rewire(&mut rng);
            }
            let ca = compute.record_iteration(stats.beta_tilde());
            if logging {
                let val_acc = if eval_every > 0 && (it % eval_every == 0 || it + 1 == iters) {
                    Some(self.evaluate(val_data, eval_sequences))
                } else {
                    None
                };
                let d = self.session.ops.since(&ops_before);
                curve.push(CurvePoint {
                    iteration: it,
                    compute_adjusted: ca,
                    loss: loss_sum / batch_size as f32,
                    accuracy: correct as f32 / batch_size as f32,
                    val_accuracy: val_acc,
                    alpha: stats.alpha(),
                    beta: stats.beta(),
                    influence_sparsity: stats.influence_sparsity(),
                    influence_macs: d.macs_in(Phase::InfluenceUpdate),
                });
            }
        }
        let final_val = self.evaluate(val_data, usize::MAX);
        TrainOutcome {
            curve,
            ops: self.session.ops.clone(),
            final_val_accuracy: final_val,
            state_memory_words: self.session.state_memory_words(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, CellKind};
    use crate::train::build_dataset;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.task.num_sequences = 200;
        cfg.train.iterations = 30;
        cfg.train.batch_size = 8;
        cfg.train.log_every = 5;
        cfg.train.eval_every = 15;
        cfg.train.eval_sequences = 20;
        cfg.model.hidden = 8;
        cfg
    }

    #[test]
    fn loss_decreases_on_spiral() {
        let cfg = tiny_cfg();
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        let first = out.curve.points.first().unwrap().loss;
        let last = out.curve.points.last().unwrap().loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn curve_has_expected_logging_cadence() {
        let cfg = tiny_cfg();
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        // iterations 0,5,10,15,20,25,29
        assert_eq!(out.curve.points.len(), 7);
        assert!(out.curve.points.iter().any(|p| p.val_accuracy.is_some()));
    }

    #[test]
    fn compute_adjusted_monotone() {
        let cfg = tiny_cfg();
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        let cas: Vec<f64> = out.curve.points.iter().map(|p| p.compute_adjusted).collect();
        for w in cas.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn tanh_control_reports_zero_beta() {
        let mut cfg = tiny_cfg();
        cfg.model.cell = CellKind::GatedTanh;
        cfg.train.algorithm = AlgorithmKind::RtrlParam;
        cfg.train.iterations = 5;
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        for p in &out.curve.points {
            assert!(p.beta < 0.05, "tanh cell should have ~0 derivative sparsity");
        }
    }

    /// A 2-layer stack trains end-to-end through the same loop, and the op
    /// counters carry a per-layer breakdown covering the influence cost.
    #[test]
    fn two_layer_stack_trains_with_layer_attribution() {
        let mut cfg = tiny_cfg();
        cfg.model.layers = 2;
        cfg.train.iterations = 20;
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        let first = out.curve.points.first().unwrap().loss;
        let last = out.curve.points.last().unwrap().loss;
        assert!(last < first, "2-layer loss did not decrease: {first} -> {last}");
        assert_eq!(out.ops.layers_tracked(), 2);
        let l0 = out.ops.macs_in_layer(0, Phase::InfluenceUpdate);
        let l1 = out.ops.macs_in_layer(1, Phase::InfluenceUpdate);
        assert!(l0 > 0 && l1 > 0);
        assert_eq!(l0 + l1, out.ops.macs_in(Phase::InfluenceUpdate));
    }

    /// Behavior preservation of the session refactor: the trainer and a
    /// hand-driven manual-policy session produce bit-identical weights after
    /// the same minibatch schedule.
    #[test]
    fn trainer_is_a_thin_session_client() {
        let cfg = tiny_cfg();
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        // replicate two iterations by hand through the session API
        let mut session = crate::session::SessionBuilder::from_config(cfg.clone())
            .policy(crate::session::UpdatePolicy::Manual)
            .build();
        let mut root = Pcg64::new(cfg.seed);
        let _ = root.split();
        let _ = root.split();
        let _ = root.split();
        let mut batch_rng = root.split();
        let mut batches =
            crate::data::BatchIter::new(train.len(), cfg.train.batch_size, batch_rng.next_u64());
        let mut tr_cfg = cfg.clone();
        tr_cfg.train.iterations = 2;
        tr_cfg.train.eval_every = 0;
        let mut tr2 = Trainer::new(tr_cfg);
        let _ = tr2.train(&train, &val);
        for _ in 0..2 {
            let idx = batches.next_batch();
            for &si in idx.iter() {
                let seq = &train.seqs[si];
                session.set_measure_influence(false);
                session.begin_sequence();
                for (t, x) in seq.inputs.iter().enumerate() {
                    session.step(x, seq.targets[t].as_target());
                }
                session.end_sequence();
            }
            session.apply_update(1.0 / cfg.train.batch_size as f32);
        }
        let mut via_trainer = vec![0.0; tr2.net().p()];
        let mut via_session = vec![0.0; session.net().p()];
        tr2.net().copy_params_into(&mut via_trainer);
        session.net().copy_params_into(&mut via_session);
        assert_eq!(via_trainer, via_session, "trainer diverged from the session path");
    }
}
