//! The training loop (paper §6 protocol): minibatch RTRL/BPTT with Adam,
//! per-iteration sparsity + compute accounting, periodic validation —
//! over a [`LayerStack`] of any depth.

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::metrics::curve::{Curve, CurvePoint};
use crate::metrics::{ComputeAdjusted, OpCounter, Phase, SparsityStats};
use crate::nn::{LayerStack, Loss, LossKind, Readout};
use crate::optim::{Adam, Optimizer};
use crate::rtrl::GradientEngine;
use crate::train::build;
use crate::util::Pcg64;

/// Everything a finished run reports.
pub struct TrainOutcome {
    pub curve: Curve,
    /// Total MACs spent, by phase (and by layer where attributable).
    pub ops: OpCounter,
    /// Final validation accuracy.
    pub final_val_accuracy: f32,
    /// Engine state memory (words) — the Table-1 memory column.
    pub state_memory_words: usize,
}

/// Single-run trainer owning all components.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub net: LayerStack,
    pub readout: Readout,
    pub loss: Loss,
    pub engine: Box<dyn GradientEngine>,
    opt_cell: Adam,
    opt_readout: Adam,
    grad_accum: Vec<f32>,
    /// Staging buffer for the concatenated stack parameters (`R^P`).
    cell_params: Vec<f32>,
    readout_params: Vec<f32>,
    readout_grads: Vec<f32>,
    batch_rng: Pcg64,
    pub ops: OpCounter,
}

impl Trainer {
    /// Build a trainer from a config. RNG streams are split per component so
    /// e.g. two algorithms see identical weight init and data order.
    pub fn new(cfg: ExperimentConfig) -> Self {
        let mut root = Pcg64::new(cfg.seed);
        let mut cell_rng = root.split();
        let mut readout_rng = root.split();
        let _data_rng = root.split(); // consumed by callers building datasets
        let batch_rng = root.split();
        let n_out = build::task_n_out(&cfg);
        let net = build::build_stack(&cfg, &mut cell_rng);
        let readout = Readout::new(n_out, net.top_n(), &mut readout_rng);
        let engine = build::build_engine(cfg.train.algorithm, &net, n_out);
        let p = net.p();
        let rp = readout.param_len();
        let lr = cfg.train.lr;
        Trainer {
            cfg,
            net,
            readout,
            loss: Loss::new(LossKind::CrossEntropy, n_out),
            engine,
            opt_cell: Adam::new(p, lr),
            opt_readout: Adam::new(rp, lr),
            grad_accum: vec![0.0; p],
            cell_params: vec![0.0; p],
            readout_params: vec![0.0; rp],
            readout_grads: vec![0.0; rp],
            batch_rng,
            ops: OpCounter::new(),
        }
    }

    /// Dataset RNG matching the stream order used by [`Trainer::new`].
    pub fn data_rng(seed: u64) -> Pcg64 {
        let mut root = Pcg64::new(seed);
        let _ = root.split();
        let _ = root.split();
        root.split()
    }

    /// Run one gradient sequence and accumulate into the batch buffers.
    /// Returns (mean step loss, final correct, sparsity observations).
    fn run_sequence(
        &mut self,
        seq: &crate::data::Sequence,
        stats: &mut SparsityStats,
        measure_influence: bool,
    ) -> (f32, bool) {
        self.engine.set_measure_influence(measure_influence);
        self.engine.begin_sequence();
        let mut loss_sum = 0.0;
        let mut loss_count = 0u32;
        let mut last_correct = false;
        let n_total = self.net.total_units();
        for (t, x) in seq.inputs.iter().enumerate() {
            let r = self.engine.step(
                &self.net,
                &mut self.readout,
                &mut self.loss,
                x,
                seq.targets[t].as_target(),
                &mut self.ops,
            );
            stats.record_step(n_total, r.active_units, r.deriv_units);
            if let Some(l) = r.loss {
                loss_sum += l;
                loss_count += 1;
            }
            if let Some(c) = r.correct {
                last_correct = c;
            }
            if let Some(s) = r.influence_sparsity {
                stats.record_influence(s);
            }
        }
        self.engine.end_sequence(&self.net, &mut self.readout, &mut self.ops);
        for (g, eg) in self.grad_accum.iter_mut().zip(self.engine.grads()) {
            *g += eg;
        }
        (loss_sum / loss_count.max(1) as f32, last_correct)
    }

    /// Apply accumulated batch gradients (mean over `batch_size`).
    fn apply_update(&mut self, batch_size: usize) {
        let scale = 1.0 / batch_size as f32;
        for g in self.grad_accum.iter_mut() {
            *g *= scale;
        }
        self.net.copy_params_into(&mut self.cell_params);
        self.opt_cell.update(&mut self.cell_params, &self.grad_accum);
        self.net.load_params(&self.cell_params);
        self.net.enforce_masks();
        self.grad_accum.iter_mut().for_each(|g| *g = 0.0);

        self.readout.scale_grads(scale);
        self.readout.copy_params_into(&mut self.readout_params);
        self.readout.copy_grads_into(&mut self.readout_grads);
        self.opt_readout.update(&mut self.readout_params, &self.readout_grads);
        self.readout.load_params(&self.readout_params);
        self.readout.zero_grads();
        self.ops.macs(Phase::Optimizer, (self.net.p() + self.readout.param_len()) as u64);
    }

    /// One Deep-Rewiring-style step (paper Discussion / Bellec et al. 2018),
    /// applied to every masked layer: relocate the lowest-magnitude kept
    /// recurrent connections, rebuild the engine (its column maps track the
    /// new patterns) and reset the Adam moments of every swapped parameter
    /// (indices in the concatenated layout).
    fn rewire(&mut self, rng: &mut Pcg64) {
        let mut swapped = Vec::new();
        let mut any = false;
        for l in 0..self.net.layers() {
            if self.net.layer(l).mask().is_none() {
                continue;
            }
            any = true;
            let old_mask = self.net.layer(l).mask().unwrap().clone();
            let new_mask = crate::sparse::rewire::magnitude_rewire(
                self.net.layer(l),
                self.cfg.train.rewire_fraction,
                rng,
            );
            // flat indices of swapped recurrent params (either direction),
            // offset into the concatenated parameter space
            let n = self.net.layer(l).n();
            let poff = self.net.layout().param_offset(l);
            let layout = self.net.layer(l).layout().clone();
            for &b in &self.net.layer(l).recurrent_blocks() {
                for r in 0..n {
                    for c in 0..n {
                        if old_mask.is_kept(r, c) != new_mask.is_kept(r, c) {
                            swapped.push(poff + layout.flat(b, r, c));
                        }
                    }
                }
            }
            // grow at ~10% of the fresh-init scale so new connections start small
            let grow = 0.1 * (6.0 / (2 * n) as f32).sqrt() / new_mask.density().sqrt();
            self.net.layer_mut(l).set_mask(new_mask, grow, rng);
        }
        if !any {
            return;
        }
        self.opt_cell.reset_indices(&swapped);
        self.engine =
            build::build_engine(self.cfg.train.algorithm, &self.net, self.readout.n_out());
    }

    /// Forward-only accuracy over (a subsample of) a dataset.
    pub fn evaluate(&self, data: &Dataset, max_sequences: usize) -> f32 {
        let mut scratch = self.net.scratch();
        let mut logits = vec![0.0; self.readout.n_out()];
        let mut discard = OpCounter::new();
        let take = data.len().min(max_sequences.max(1));
        let mut correct = 0usize;
        let mut total = 0usize;
        for seq in data.seqs.iter().take(take) {
            let mut a_prev = vec![0.0; self.net.total_units()];
            for (t, x) in seq.inputs.iter().enumerate() {
                self.net.forward(&a_prev, x, &mut scratch, &mut discard);
                if let crate::data::StepTarget::Class(c) = &seq.targets[t] {
                    self.readout.forward(&scratch.top().a, &mut logits, &mut discard);
                    total += 1;
                    if Loss::predict(&logits) == *c {
                        correct += 1;
                    }
                }
                scratch.write_state(&mut a_prev);
            }
        }
        if total == 0 {
            0.0
        } else {
            correct as f32 / total as f32
        }
    }

    /// Full training loop per the config. Returns curve + cost accounting.
    pub fn train(&mut self, train_data: &Dataset, val_data: &Dataset) -> TrainOutcome {
        let iters = self.cfg.train.iterations;
        let batch_size = self.cfg.train.batch_size;
        let log_every = self.cfg.train.log_every.max(1);
        let eval_every = self.cfg.train.eval_every;
        let activity_sparse = self.cfg.model.cell.is_event_based();
        let mut compute = ComputeAdjusted::new(self.cfg.omega_tilde(), activity_sparse);
        let mut batches = crate::data::BatchIter::new(
            train_data.len(),
            batch_size,
            self.batch_rng.next_u64(),
        );
        let mut curve = Curve::new();
        for it in 0..iters {
            let logging = it % log_every == 0 || it + 1 == iters;
            let mut stats = SparsityStats::new();
            let ops_before = self.ops.clone();
            let idx = batches.next_batch();
            let mut loss_sum = 0.0;
            let mut correct = 0usize;
            for (bi, &si) in idx.iter().enumerate() {
                // influence scan only on the first sequence of a logging iter
                let seq = &train_data.seqs[si];
                let (l, c) = self.run_sequence(seq, &mut stats, logging && bi == 0);
                loss_sum += l;
                if c {
                    correct += 1;
                }
            }
            self.apply_update(batch_size);
            if self.cfg.train.rewire_every > 0
                && it > 0
                && it % self.cfg.train.rewire_every == 0
            {
                let mut rng = Pcg64::new(self.cfg.seed ^ (0x5e71_4e00 + it));
                self.rewire(&mut rng);
            }
            let ca = compute.record_iteration(stats.beta_tilde());
            if logging {
                let val_acc = if eval_every > 0 && (it % eval_every == 0 || it + 1 == iters) {
                    Some(self.evaluate(val_data, self.cfg.train.eval_sequences))
                } else {
                    None
                };
                let d = self.ops.since(&ops_before);
                curve.push(CurvePoint {
                    iteration: it,
                    compute_adjusted: ca,
                    loss: loss_sum / batch_size as f32,
                    accuracy: correct as f32 / batch_size as f32,
                    val_accuracy: val_acc,
                    alpha: stats.alpha(),
                    beta: stats.beta(),
                    influence_sparsity: stats.influence_sparsity(),
                    influence_macs: d.macs_in(Phase::InfluenceUpdate),
                });
            }
        }
        let final_val = self.evaluate(val_data, usize::MAX);
        TrainOutcome {
            curve,
            ops: self.ops.clone(),
            final_val_accuracy: final_val,
            state_memory_words: self.engine.state_memory_words(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, CellKind};
    use crate::train::build_dataset;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.task.num_sequences = 200;
        cfg.train.iterations = 30;
        cfg.train.batch_size = 8;
        cfg.train.log_every = 5;
        cfg.train.eval_every = 15;
        cfg.train.eval_sequences = 20;
        cfg.model.hidden = 8;
        cfg
    }

    #[test]
    fn loss_decreases_on_spiral() {
        let cfg = tiny_cfg();
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        let first = out.curve.points.first().unwrap().loss;
        let last = out.curve.points.last().unwrap().loss;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn curve_has_expected_logging_cadence() {
        let cfg = tiny_cfg();
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        // iterations 0,5,10,15,20,25,29
        assert_eq!(out.curve.points.len(), 7);
        assert!(out.curve.points.iter().any(|p| p.val_accuracy.is_some()));
    }

    #[test]
    fn compute_adjusted_monotone() {
        let cfg = tiny_cfg();
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        let cas: Vec<f64> = out.curve.points.iter().map(|p| p.compute_adjusted).collect();
        for w in cas.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn tanh_control_reports_zero_beta() {
        let mut cfg = tiny_cfg();
        cfg.model.cell = CellKind::GatedTanh;
        cfg.train.algorithm = AlgorithmKind::RtrlParam;
        cfg.train.iterations = 5;
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        for p in &out.curve.points {
            assert!(p.beta < 0.05, "tanh cell should have ~0 derivative sparsity");
        }
    }

    /// A 2-layer stack trains end-to-end through the same loop, and the op
    /// counters carry a per-layer breakdown covering the influence cost.
    #[test]
    fn two_layer_stack_trains_with_layer_attribution() {
        let mut cfg = tiny_cfg();
        cfg.model.layers = 2;
        cfg.train.iterations = 20;
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg);
        let out = tr.train(&train, &val);
        let first = out.curve.points.first().unwrap().loss;
        let last = out.curve.points.last().unwrap().loss;
        assert!(last < first, "2-layer loss did not decrease: {first} -> {last}");
        assert_eq!(out.ops.layers_tracked(), 2);
        let l0 = out.ops.macs_in_layer(0, Phase::InfluenceUpdate);
        let l1 = out.ops.macs_in_layer(1, Phase::InfluenceUpdate);
        assert!(l0 > 0 && l1 > 0);
        assert_eq!(l0 + l1, out.ops.macs_in(Phase::InfluenceUpdate));
    }
}
