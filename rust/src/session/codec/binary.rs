//! The versioned binary snapshot container — byte-level layout and codec.
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"SRTLSNAP"
//! 8       4     u32 LE schema version (currently 1)
//! 12      4     u32 LE section count
//! 16      …     sections, back to back
//! ```
//!
//! Each section is one checkpoint field group, framed as:
//!
//! ```text
//! u16 LE  name length
//! …       name bytes (UTF-8: "meta", "config", "params", "optim",
//!         "masks", "ops", "engine")
//! u32 LE  CRC32 of the payload bytes (crate::util::crc32)
//! u64 LE  payload length in bytes
//! …       zero padding to the next 8-byte boundary
//! …       payload bytes
//! …       zero padding to the next 8-byte boundary
//! ```
//!
//! Payloads therefore always start 8-byte aligned in the file — an
//! mmap-friendly property: a reader that maps the snapshot can view the
//! `f32`/`u64` bulk arrays in place on any platform where unaligned access
//! is costly. Inside payloads, all integers are little-endian and every
//! `f32` is its IEEE-754 bit pattern in little-endian byte order, so
//! restores are bit-exact (negative zeros, denormals and infinities
//! included). Sections are looked up by name: unknown extra sections are
//! ignored (forward-compatible within a schema version), missing required
//! sections and duplicate names are errors.
//!
//! Corruption handling is the point of the framing: every decode path
//! checks declared lengths against the remaining bytes **before**
//! allocating, and every payload is CRC-checked before parsing, so a
//! truncated file or a flipped bit yields a typed [`CodecError`] naming
//! the damaged section — never a panic, never a silently wrong resume.

use super::super::checkpoint::{policy_from, policy_name, SessionCheckpoint};
use super::{CodecError, SnapshotCodec, SnapshotFormat};
use crate::optim::AdamState;
use crate::rtrl::EngineState;
use crate::util::crc32::crc32;

/// Leading magic of every binary snapshot. Starts with an uppercase ASCII
/// letter, so it can never be confused with a JSON document (which the
/// autodetector requires to start with `{`).
pub const MAGIC: [u8; 8] = *b"SRTLSNAP";

/// Container schema version. Bump on any layout change; old builds then
/// reject newer snapshots loudly ([`CodecError::UnsupportedVersion`]).
pub const SCHEMA_VERSION: u32 = 1;

/// Alignment of section payloads within the file.
const ALIGN: usize = 8;

const SEC_META: &str = "meta";
const SEC_CONFIG: &str = "config";
const SEC_PARAMS: &str = "params";
const SEC_OPTIM: &str = "optim";
const SEC_MASKS: &str = "masks";
const SEC_OPS: &str = "ops";
const SEC_ENGINE: &str = "engine";

/// The required sections, in the order [`BinaryCodec::encode`] writes them.
/// Encoder table: section order *and* the writer for each section live in
/// one place, so a section can never be listed without a payload writer.
const SECTIONS: [(&str, fn(&SessionCheckpoint, &mut Payload)); 7] = [
    (SEC_META, payload_meta),
    (SEC_CONFIG, payload_config),
    (SEC_PARAMS, payload_params),
    (SEC_OPTIM, payload_optim),
    (SEC_MASKS, payload_masks),
    (SEC_OPS, payload_ops),
    (SEC_ENGINE, payload_engine),
];

/// The binary [`SnapshotCodec`]. Stateless; see the module docs for the
/// layout.
pub struct BinaryCodec;

impl SnapshotCodec for BinaryCodec {
    fn format(&self) -> SnapshotFormat {
        SnapshotFormat::Binary
    }

    fn encode(&self, ck: &SessionCheckpoint) -> Vec<u8> {
        encode(ck)
    }

    fn decode(&self, bytes: &[u8]) -> Result<SessionCheckpoint, CodecError> {
        decode(bytes)
    }

    fn sniff(&self, bytes: &[u8]) -> bool {
        bytes.starts_with(&MAGIC)
    }
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

/// Little-endian payload writer.
#[derive(Default)]
struct Payload {
    buf: Vec<u8>,
}

impl Payload {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// u16-length-prefixed UTF-8 string (names are short by construction;
    /// longer ones are truncated at a char boundary rather than panicking).
    fn str16(&mut self, s: &str) {
        let mut end = s.len().min(u16::MAX as usize);
        while !s.is_char_boundary(end) {
            end -= 1;
        }
        self.u16(end as u16);
        self.buf.extend_from_slice(&s.as_bytes()[..end]);
    }

    /// u64-count-prefixed f32 array (LE bit patterns).
    fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    /// u64-count-prefixed u64 array.
    fn u64s(&mut self, xs: &[u64]) {
        self.u64(xs.len() as u64);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn pad_to(buf: &mut Vec<u8>, align: usize) {
    while buf.len() % align != 0 {
        buf.push(0);
    }
}

fn write_section(out: &mut Vec<u8>, name: &str, payload: &[u8]) {
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    pad_to(out, ALIGN);
    out.extend_from_slice(payload);
    pad_to(out, ALIGN);
}

fn payload_meta(ck: &SessionCheckpoint, p: &mut Payload) {
    let (policy, k) = policy_name(ck.policy);
    p.str16(policy);
    p.u8(ck.predict_always as u8);
    p.u64(k);
    p.u64(ck.steps);
    p.u64(ck.supervised_steps);
    p.u64(ck.updates_applied);
    p.u64(ck.pending_supervised);
}

fn payload_config(ck: &SessionCheckpoint, p: &mut Payload) {
    p.buf.extend_from_slice(ck.config_toml.as_bytes());
}

fn payload_params(ck: &SessionCheckpoint, p: &mut Payload) {
    p.f32s(&ck.net_params);
    p.f32s(&ck.readout_params);
    p.f32s(&ck.readout_grads);
    p.f32s(&ck.grad_accum);
}

fn payload_optim(ck: &SessionCheckpoint, p: &mut Payload) {
    for opt in [&ck.opt_cell, &ck.opt_readout] {
        p.u64(opt.t);
        p.f32s(&opt.m);
        p.f32s(&opt.v);
    }
}

fn payload_masks(ck: &SessionCheckpoint, p: &mut Payload) {
    p.u64(ck.masks.len() as u64);
    for m in &ck.masks {
        match m {
            None => p.u8(0),
            Some(kept) => {
                p.u8(1);
                p.u64s(kept);
            }
        }
    }
}

fn payload_ops(ck: &SessionCheckpoint, p: &mut Payload) {
    p.u64s(&ck.ops);
}

fn payload_engine(ck: &SessionCheckpoint, p: &mut Payload) {
    p.str16(&ck.engine.engine);
    p.u32(ck.engine.version);
    let ints: Vec<_> = ck.engine.int_entries().collect();
    p.u32(ints.len() as u32);
    for (key, v) in ints {
        p.str16(key);
        p.u64s(v);
    }
    let floats: Vec<_> = ck.engine.float_entries().collect();
    p.u32(floats.len() as u32);
    for (key, v) in floats {
        p.str16(key);
        p.f32s(v);
    }
}

/// Serialize a checkpoint into the binary container.
pub fn encode(ck: &SessionCheckpoint) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + 5 * ck.net_params.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&SCHEMA_VERSION.to_le_bytes());
    out.extend_from_slice(&(SECTIONS.len() as u32).to_le_bytes());
    for (name, write_payload) in SECTIONS {
        let mut p = Payload::default();
        write_payload(ck, &mut p);
        write_section(&mut out, name, &p.buf);
    }
    out
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// Bounds-checked little-endian reader over one section's payload. Every
/// error names the section; declared counts are validated against the
/// remaining bytes **before** any allocation.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
    section: &'a str,
}

impl<'a> Cur<'a> {
    fn new(section: &'a str, b: &'a [u8]) -> Self {
        Cur { b, pos: 0, section }
    }

    fn truncated(&self) -> CodecError {
        CodecError::Truncated { section: self.section.to_string() }
    }

    fn malformed(&self, detail: impl Into<String>) -> CodecError {
        CodecError::Malformed { section: self.section.to_string(), detail: detail.into() }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if n > self.remaining() {
            return Err(self.truncated());
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, CodecError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Declared element count, validated against `bytes_per_elem` of
    /// remaining payload so a corrupted length can never trigger a huge
    /// allocation.
    fn count(&mut self, bytes_per_elem: usize) -> Result<usize, CodecError> {
        let n = self.u64()?;
        if n > (self.remaining() / bytes_per_elem) as u64 {
            return Err(self.truncated());
        }
        Ok(n as usize)
    }

    fn str16(&mut self) -> Result<String, CodecError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.malformed("non-UTF-8 string"))
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.count(4)?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    fn u64_vec(&mut self) -> Result<Vec<u64>, CodecError> {
        let n = self.count(8)?;
        let bytes = self.take(n * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| {
                u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
            })
            .collect())
    }

    /// The payload must be fully consumed — trailing bytes mean the writer
    /// and reader disagree about the section layout.
    fn finish(&self) -> Result<(), CodecError> {
        if self.pos != self.b.len() {
            return Err(self.malformed(format!(
                "{} trailing bytes after the last field",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn align_up(pos: usize, align: usize) -> usize {
    pos.div_ceil(align) * align
}

/// Parse the container framing: magic, version, and the CRC-verified
/// section directory. Returns `(name, payload)` pairs.
fn directory(bytes: &[u8]) -> Result<Vec<(String, &[u8])>, CodecError> {
    let bad = |detail: &str| CodecError::BadHeader { detail: detail.to_string() };
    if bytes.len() < 16 {
        return Err(bad("file shorter than the 16-byte header"));
    }
    if bytes[..8] != MAGIC {
        return Err(bad("wrong magic (not a sparse-rtrl binary snapshot)"));
    }
    let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
    if version == 0 || version > SCHEMA_VERSION {
        return Err(CodecError::UnsupportedVersion { found: version, supported: SCHEMA_VERSION });
    }
    let count = u32::from_le_bytes([bytes[12], bytes[13], bytes[14], bytes[15]]) as usize;
    // every section needs ≥ 14 framing bytes, so an absurd count is a
    // corrupted header, not a reason to loop
    if count > (bytes.len() - 16) / 14 {
        return Err(bad("section count exceeds what the file can hold"));
    }
    let mut sections: Vec<(String, &[u8])> = Vec::with_capacity(count);
    let mut pos = 16usize;
    for _ in 0..count {
        // section framing; until the name is known, errors blame the directory
        let mut cur = Cur::new("directory", &bytes[pos..]);
        let name = cur.str16()?;
        let stored = cur.u32()?;
        let len = cur.u64()?;
        let payload_start = align_up(pos + cur.pos, ALIGN);
        let payload_end = payload_start
            .checked_add(usize::try_from(len).map_err(|_| CodecError::Truncated {
                section: name.clone(),
            })?)
            .ok_or_else(|| CodecError::Truncated { section: name.clone() })?;
        if payload_end > bytes.len() {
            return Err(CodecError::Truncated { section: name });
        }
        let payload = &bytes[payload_start..payload_end];
        let computed = crc32(payload);
        if computed != stored {
            return Err(CodecError::Checksum { section: name, stored, computed });
        }
        if sections.iter().any(|(n, _)| *n == name) {
            return Err(CodecError::Malformed {
                section: name,
                detail: "duplicate section".into(),
            });
        }
        sections.push((name, payload));
        pos = align_up(payload_end, ALIGN);
    }
    if pos != bytes.len() {
        return Err(bad("trailing bytes after the last section"));
    }
    Ok(sections)
}

fn section<'a>(
    sections: &'a [(String, &'a [u8])],
    name: &'static str,
) -> Result<Cur<'a>, CodecError> {
    sections
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, payload)| Cur::new(name, payload))
        .ok_or_else(|| CodecError::MissingSection { section: name.to_string() })
}

fn decode_adam(cur: &mut Cur<'_>) -> Result<AdamState, CodecError> {
    let t = cur.u64()?;
    let m = cur.f32_vec()?;
    let v = cur.f32_vec()?;
    Ok(AdamState { m, v, t })
}

/// Parse a binary snapshot back into a checkpoint, bit-exactly.
pub fn decode(bytes: &[u8]) -> Result<SessionCheckpoint, CodecError> {
    let sections = directory(bytes)?;

    let mut meta = section(&sections, SEC_META)?;
    let policy_tag = meta.str16()?;
    let predict_always = match meta.u8()? {
        0 => false,
        1 => true,
        other => return Err(meta.malformed(format!("predict_always byte {other} not 0/1"))),
    };
    let k = meta.u64()?;
    let policy = policy_from(&policy_tag, k).map_err(|e| meta.malformed(e))?;
    let steps = meta.u64()?;
    let supervised_steps = meta.u64()?;
    let updates_applied = meta.u64()?;
    let pending_supervised = meta.u64()?;
    meta.finish()?;

    let mut config = section(&sections, SEC_CONFIG)?;
    let config_bytes = config.take(config.remaining())?;
    let config_toml = String::from_utf8(config_bytes.to_vec())
        .map_err(|_| config.malformed("config TOML is not UTF-8"))?;

    let mut params = section(&sections, SEC_PARAMS)?;
    let net_params = params.f32_vec()?;
    let readout_params = params.f32_vec()?;
    let readout_grads = params.f32_vec()?;
    let grad_accum = params.f32_vec()?;
    params.finish()?;

    let mut optim = section(&sections, SEC_OPTIM)?;
    let opt_cell = decode_adam(&mut optim)?;
    let opt_readout = decode_adam(&mut optim)?;
    optim.finish()?;

    let mut masks_cur = section(&sections, SEC_MASKS)?;
    let n_layers = {
        let n = masks_cur.u64()?;
        // each layer contributes at least its presence byte
        if n > masks_cur.remaining() as u64 {
            return Err(masks_cur.truncated());
        }
        n as usize
    };
    let mut masks = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        masks.push(match masks_cur.u8()? {
            0 => None,
            1 => Some(masks_cur.u64_vec()?),
            other => {
                return Err(masks_cur.malformed(format!("mask presence byte {other} not 0/1")))
            }
        });
    }
    masks_cur.finish()?;

    let mut ops_cur = section(&sections, SEC_OPS)?;
    let ops = ops_cur.u64_vec()?;
    ops_cur.finish()?;

    let mut eng = section(&sections, SEC_ENGINE)?;
    let engine_name = eng.str16()?;
    let engine_version = eng.u32()?;
    let mut engine = EngineState::new(&engine_name, engine_version);
    let n_ints = eng.u32()? as usize;
    for _ in 0..n_ints {
        let key = eng.str16()?;
        let v = eng.u64_vec()?;
        engine.put_ints(&key, v);
    }
    let n_floats = eng.u32()? as usize;
    for _ in 0..n_floats {
        let key = eng.str16()?;
        let v = eng.f32_vec()?;
        engine.put_floats(&key, v);
    }
    eng.finish()?;

    Ok(SessionCheckpoint {
        config_toml,
        policy,
        predict_always,
        steps,
        supervised_steps,
        updates_applied,
        pending_supervised,
        net_params,
        readout_params,
        readout_grads,
        grad_accum,
        opt_cell,
        opt_readout,
        masks,
        ops,
        engine,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::SessionBuilder;

    fn checkpoint() -> SessionCheckpoint {
        let mut s = SessionBuilder::new().hidden(6).param_sparsity(0.5).build();
        for i in 0..6 {
            let t = if i % 2 == 0 {
                crate::rtrl::Target::Class(i % 2)
            } else {
                crate::rtrl::Target::None
            };
            s.step(&[0.1 * i as f32, -0.4], t);
        }
        s.checkpoint()
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let ck = checkpoint();
        let bytes = encode(&ck);
        assert_eq!(&bytes[..8], &MAGIC);
        let back = decode(&bytes).expect("decode");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(back.config_toml, ck.config_toml);
        assert_eq!(back.policy, ck.policy);
        assert_eq!(back.predict_always, ck.predict_always);
        assert_eq!(
            (back.steps, back.supervised_steps, back.updates_applied, back.pending_supervised),
            (ck.steps, ck.supervised_steps, ck.updates_applied, ck.pending_supervised)
        );
        assert_eq!(bits(&back.net_params), bits(&ck.net_params));
        assert_eq!(bits(&back.readout_params), bits(&ck.readout_params));
        assert_eq!(bits(&back.readout_grads), bits(&ck.readout_grads));
        assert_eq!(bits(&back.grad_accum), bits(&ck.grad_accum));
        assert_eq!(bits(&back.opt_cell.m), bits(&ck.opt_cell.m));
        assert_eq!(bits(&back.opt_cell.v), bits(&ck.opt_cell.v));
        assert_eq!(back.opt_cell.t, ck.opt_cell.t);
        assert_eq!(bits(&back.opt_readout.m), bits(&ck.opt_readout.m));
        assert_eq!(back.opt_readout.t, ck.opt_readout.t);
        assert_eq!(back.masks, ck.masks);
        assert_eq!(back.ops, ck.ops);
        assert_eq!(back.engine, ck.engine);
    }

    #[test]
    fn special_float_bit_patterns_survive() {
        let mut ck = checkpoint();
        ck.grad_accum[0] = -0.0;
        ck.grad_accum[1] = f32::from_bits(1); // smallest denormal
        ck.grad_accum[2] = f32::NEG_INFINITY;
        ck.grad_accum[3] = f32::from_bits(0x7fc0_1234); // a specific NaN
        let back = decode(&encode(&ck)).unwrap();
        assert_eq!(back.grad_accum[0].to_bits(), (-0.0f32).to_bits());
        assert_eq!(back.grad_accum[1].to_bits(), 1);
        assert_eq!(back.grad_accum[2], f32::NEG_INFINITY);
        assert_eq!(back.grad_accum[3].to_bits(), 0x7fc0_1234);
    }

    /// Every section payload starts on an 8-byte boundary (the mmap
    /// contract from the module docs).
    #[test]
    fn payloads_are_8_byte_aligned() {
        let bytes = encode(&checkpoint());
        let dir = directory(&bytes).unwrap();
        assert_eq!(dir.len(), SECTIONS.len());
        for (name, payload) in &dir {
            let offset = payload.as_ptr() as usize - bytes.as_ptr() as usize;
            assert_eq!(offset % ALIGN, 0, "section {name:?} payload misaligned at {offset}");
        }
    }

    #[test]
    fn wrong_magic_is_rejected() {
        let mut bytes = encode(&checkpoint());
        bytes[0] = b'X';
        match decode(&bytes) {
            Err(CodecError::BadHeader { detail }) => assert!(detail.contains("magic"), "{detail}"),
            other => panic!("expected BadHeader, got {other:?}"),
        }
    }

    #[test]
    fn future_schema_version_is_rejected() {
        let mut bytes = encode(&checkpoint());
        bytes[8..12].copy_from_slice(&(SCHEMA_VERSION + 1).to_le_bytes());
        match decode(&bytes) {
            Err(CodecError::UnsupportedVersion { found, supported }) => {
                assert_eq!(found, SCHEMA_VERSION + 1);
                assert_eq!(supported, SCHEMA_VERSION);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn truncation_names_a_section() {
        let bytes = encode(&checkpoint());
        let cut = decode(&bytes[..bytes.len() - 9]);
        match cut {
            Err(
                CodecError::Truncated { .. }
                | CodecError::BadHeader { .. }
                | CodecError::Checksum { .. },
            ) => {}
            other => panic!("truncation must be a framing error, got {other:?}"),
        }
    }

    #[test]
    fn flipped_payload_byte_fails_the_section_checksum() {
        let bytes = encode(&checkpoint());
        // locate the "params" section payload and flip a byte inside it
        let dir = directory(&bytes).unwrap();
        let (_, payload) =
            dir.iter().find(|(n, _)| n == SEC_PARAMS).expect("params section present");
        let offset = payload.as_ptr() as usize - bytes.as_ptr() as usize;
        let mut corrupt = bytes.clone();
        corrupt[offset + payload.len() / 2] ^= 0x10;
        match decode(&corrupt) {
            Err(CodecError::Checksum { section, .. }) => assert_eq!(section, SEC_PARAMS),
            other => panic!("expected a params checksum error, got {other:?}"),
        }
    }
}
