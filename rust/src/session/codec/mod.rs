//! One entry point for snapshot serialization: the [`SnapshotCodec`]
//! facade over the checkpoint wire formats.
//!
//! Everything that persists or ingests a [`SessionCheckpoint`] — the
//! `stream` CLI's `--checkpoint`/`--resume`, [`crate::session::SessionPool`]
//! eviction, the bench subsystem's codec measurements — goes through this
//! module instead of hard-coding a format. Two codecs implement the trait:
//!
//! * **Binary** ([`BinaryCodec`], the default spill format): a versioned
//!   container — 8-byte magic, `u32` schema version, then length-prefixed
//!   named sections, one per checkpoint field group (`meta`, `config`,
//!   `params`, `optim`, `masks`, `ops`, `engine`). Every section payload is
//!   8-byte aligned (mmap-friendly) and carries a CRC32 checksum, so a
//!   flipped bit in a spilled checkpoint fails loudly on load — naming the
//!   damaged section — instead of resuming a session from corrupted state.
//!   All `f32`s travel as little-endian IEEE-754 bit patterns; restores are
//!   bit-exact. See [`binary`] for the byte-level layout.
//! * **JSON** ([`JsonCodec`], the debug interchange): the
//!   [`SessionCheckpoint::to_json`] document, human-inspectable and
//!   diff-able, with f32s as bit-pattern numbers. Behavior is pinned —
//!   the binary format is required to round-trip bit-identically against
//!   it (`rust/tests/snapshot_codec.rs`).
//!
//! Loading always **autodetects** the format from the leading bytes
//! ([`detect`]): the binary magic cannot begin a JSON document and vice
//! versa, so `--resume` and [`decode`] accept either format transparently.

pub mod binary;

use super::checkpoint::SessionCheckpoint;
use std::fmt;

pub use binary::BinaryCodec;

/// The snapshot wire formats the facade dispatches between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotFormat {
    /// Versioned binary container with per-section CRC32 checksums — the
    /// spill format for eviction loops.
    Binary,
    /// The JSON debug interchange ([`SessionCheckpoint::to_json`]).
    Json,
}

impl SnapshotFormat {
    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            SnapshotFormat::Binary => "binary",
            SnapshotFormat::Json => "json",
        }
    }

    /// Inverse of [`SnapshotFormat::name`].
    pub fn from_name(name: &str) -> Option<SnapshotFormat> {
        match name {
            "binary" => Some(SnapshotFormat::Binary),
            "json" => Some(SnapshotFormat::Json),
            _ => None,
        }
    }

    /// Every format, registry-style (CLI error messages).
    pub fn all() -> [SnapshotFormat; 2] {
        [SnapshotFormat::Binary, SnapshotFormat::Json]
    }

    /// Format conventionally implied by a file path: `.json` means the
    /// debug interchange, anything else the binary spill format.
    pub fn for_path(path: &str) -> SnapshotFormat {
        if path.to_ascii_lowercase().ends_with(".json") {
            SnapshotFormat::Json
        } else {
            SnapshotFormat::Binary
        }
    }
}

impl fmt::Display for SnapshotFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Why a snapshot failed to decode. Binary-side variants name the section
/// at fault so corruption reports point at the damaged field group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// Autodetection failed: the bytes start with neither the binary magic
    /// nor a JSON document.
    UnknownFormat,
    /// The binary header is damaged (bad magic or header truncation).
    BadHeader { detail: String },
    /// The snapshot was written by a future schema revision.
    UnsupportedVersion { found: u32, supported: u32 },
    /// A section (or the file itself) ends before its declared length.
    Truncated { section: String },
    /// A section's stored CRC32 does not match its payload.
    Checksum { section: String, stored: u32, computed: u32 },
    /// A section is structurally intact but its contents are invalid.
    Malformed { section: String, detail: String },
    /// A required section is absent from the container.
    MissingSection { section: String },
    /// The JSON interchange document failed to parse.
    Json(String),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::UnknownFormat => write!(
                f,
                "snapshot format not recognized (neither the binary magic nor a JSON document)"
            ),
            CodecError::BadHeader { detail } => {
                write!(f, "snapshot section \"header\": {detail}")
            }
            CodecError::UnsupportedVersion { found, supported } => write!(
                f,
                "snapshot section \"header\": schema version {found} unsupported \
                 (this build reads ≤ {supported})"
            ),
            CodecError::Truncated { section } => {
                write!(f, "snapshot section {section:?}: truncated")
            }
            CodecError::Checksum { section, stored, computed } => write!(
                f,
                "snapshot section {section:?}: checksum mismatch \
                 (stored {stored:#010x}, computed {computed:#010x}) — the snapshot is corrupted"
            ),
            CodecError::Malformed { section, detail } => {
                write!(f, "snapshot section {section:?}: {detail}")
            }
            CodecError::MissingSection { section } => {
                write!(f, "snapshot section {section:?}: missing")
            }
            CodecError::Json(e) => write!(f, "json snapshot: {e}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// One snapshot wire format: encode to bytes, decode from bytes, and sniff
/// whether a byte prefix belongs to this format.
pub trait SnapshotCodec: Sync {
    /// Which [`SnapshotFormat`] this codec implements.
    fn format(&self) -> SnapshotFormat;

    /// Serialize a checkpoint. Infallible: every in-memory checkpoint has a
    /// representation in every format.
    fn encode(&self, ck: &SessionCheckpoint) -> Vec<u8>;

    /// Parse a checkpoint; bit-exact for every `f32`/`u64` field.
    fn decode(&self, bytes: &[u8]) -> Result<SessionCheckpoint, CodecError>;

    /// Whether `bytes` plausibly starts a document of this format (cheap
    /// prefix test, used by [`detect`]).
    fn sniff(&self, bytes: &[u8]) -> bool;
}

/// The JSON debug-interchange codec — a thin [`SnapshotCodec`] wrapper over
/// the pinned [`SessionCheckpoint::to_json`] / [`SessionCheckpoint::from_json`]
/// document.
pub struct JsonCodec;

impl SnapshotCodec for JsonCodec {
    fn format(&self) -> SnapshotFormat {
        SnapshotFormat::Json
    }

    fn encode(&self, ck: &SessionCheckpoint) -> Vec<u8> {
        ck.to_json().into_bytes()
    }

    fn decode(&self, bytes: &[u8]) -> Result<SessionCheckpoint, CodecError> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| CodecError::Json("document is not UTF-8".into()))?;
        SessionCheckpoint::from_json(text).map_err(CodecError::Json)
    }

    fn sniff(&self, bytes: &[u8]) -> bool {
        bytes.iter().find(|b| !b.is_ascii_whitespace()) == Some(&b'{')
    }
}

static BINARY: BinaryCodec = BinaryCodec;
static JSON: JsonCodec = JsonCodec;

/// The codec implementing `format`.
pub fn codec_for(format: SnapshotFormat) -> &'static dyn SnapshotCodec {
    match format {
        SnapshotFormat::Binary => &BINARY,
        SnapshotFormat::Json => &JSON,
    }
}

/// Serialize a checkpoint in the chosen format.
pub fn encode(ck: &SessionCheckpoint, format: SnapshotFormat) -> Vec<u8> {
    codec_for(format).encode(ck)
}

/// Identify the format of serialized snapshot bytes from their prefix.
/// The binary magic can never begin a JSON document, so detection is
/// unambiguous.
pub fn detect(bytes: &[u8]) -> Option<SnapshotFormat> {
    SnapshotFormat::all().into_iter().find(|&f| codec_for(f).sniff(bytes))
}

/// Parse a snapshot of either format, autodetecting from the bytes — the
/// single ingestion entry point `--resume`, pool admission and tests use.
pub fn decode(bytes: &[u8]) -> Result<SessionCheckpoint, CodecError> {
    match detect(bytes) {
        Some(format) => codec_for(format).decode(bytes),
        None => Err(CodecError::UnknownFormat),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;
    use crate::rtrl::Target;
    use crate::session::SessionBuilder;

    fn driven_checkpoint() -> SessionCheckpoint {
        let mut s = SessionBuilder::new()
            .algorithm(AlgorithmKind::RtrlBoth)
            .hidden(8)
            .param_sparsity(0.5)
            .build();
        for i in 0..9 {
            let x = [0.2 * i as f32 - 0.7, (i as f32 * 0.5).sin()];
            let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
            s.step(&x, t);
        }
        s.checkpoint()
    }

    #[test]
    fn format_names_round_trip() {
        for f in SnapshotFormat::all() {
            assert_eq!(SnapshotFormat::from_name(f.name()), Some(f));
        }
        assert_eq!(SnapshotFormat::from_name("msgpack"), None);
        assert_eq!(SnapshotFormat::for_path("ck.json"), SnapshotFormat::Json);
        assert_eq!(SnapshotFormat::for_path("CK.JSON"), SnapshotFormat::Json);
        assert_eq!(SnapshotFormat::for_path("ck.snap"), SnapshotFormat::Binary);
        assert_eq!(SnapshotFormat::for_path("ck"), SnapshotFormat::Binary);
    }

    #[test]
    fn detection_is_unambiguous() {
        let ck = driven_checkpoint();
        for f in SnapshotFormat::all() {
            let bytes = encode(&ck, f);
            assert_eq!(detect(&bytes), Some(f), "{f} bytes misdetected");
        }
        assert_eq!(detect(b"plain text, not a snapshot"), None);
        assert_eq!(detect(b""), None);
        assert!(decode(b"garbage").is_err());
    }

    /// Both codecs round-trip through the facade's autodetecting `decode`,
    /// and the two decoded checkpoints agree bit-for-bit.
    #[test]
    fn both_formats_round_trip_and_agree() {
        let ck = driven_checkpoint();
        let from_json = decode(&encode(&ck, SnapshotFormat::Json)).expect("json round-trip");
        let from_bin = decode(&encode(&ck, SnapshotFormat::Binary)).expect("binary round-trip");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for decoded in [&from_json, &from_bin] {
            assert_eq!(decoded.config_toml, ck.config_toml);
            assert_eq!(decoded.policy, ck.policy);
            assert_eq!(decoded.steps, ck.steps);
            assert_eq!(bits(&decoded.net_params), bits(&ck.net_params));
            assert_eq!(bits(&decoded.opt_cell.m), bits(&ck.opt_cell.m));
            assert_eq!(decoded.opt_cell.t, ck.opt_cell.t);
            assert_eq!(decoded.masks, ck.masks);
            assert_eq!(decoded.ops, ck.ops);
            assert_eq!(decoded.engine, ck.engine);
        }
    }

    /// The binary format earns its keep: at least 3× smaller than the JSON
    /// interchange on a real (driven, sparse, multi-buffer) checkpoint.
    #[test]
    fn binary_is_at_least_3x_smaller_than_json() {
        let ck = driven_checkpoint();
        let json = encode(&ck, SnapshotFormat::Json).len();
        let bin = encode(&ck, SnapshotFormat::Binary).len();
        assert!(
            bin * 3 <= json,
            "binary snapshot ({bin} B) is not 3× smaller than JSON ({json} B)"
        );
    }
}
