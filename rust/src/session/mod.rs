//! Streaming online learning — the crate's primary API surface.
//!
//! RTRL's defining capability is learning from an **endless stream** with
//! memory independent of stream length. This module is that capability as
//! an API:
//!
//! * [`SessionBuilder`] → [`OnlineSession`]: a long-lived learner whose core
//!   call is [`OnlineSession::step`]`(input, target) → `[`StepOutcome`]
//!   (prediction, loss, sparsity stats). No mandatory sequence boundaries;
//!   an [`UpdatePolicy`] decides when accumulated gradients become
//!   parameter updates (every-k-supervised-steps, end-of-sequence, or
//!   manual).
//! * [`OnlineSession::checkpoint`] / [`OnlineSession::resume`]: migrate a
//!   session across process restarts **bit-exactly** — weights, optimizer
//!   moments, stream counters and the engine's versioned
//!   [`crate::rtrl::EngineState`] snapshot (influence panels, UORO rank-1
//!   vectors + noise-RNG position, SnAp slabs, the BPTT tape) all travel in
//!   one [`SessionCheckpoint`] ([`checkpoint`]).
//! * [`codec`]: the snapshot wire formats — a versioned, CRC-checksummed
//!   binary container ([`SnapshotFormat::Binary`], the spill format) and
//!   the JSON debug interchange ([`SnapshotFormat::Json`]) — behind one
//!   [`codec::SnapshotCodec`] facade with format autodetection on load.
//! * [`SessionPool`]: N independent sessions (one per user) stepped
//!   concurrently over the in-tree worker pool, with codec-backed
//!   [`SessionPool::evict`] / [`SessionPool::admit`] for spilling idle
//!   sessions to disk.
//! * [`events`]: event-stream ingestion for the `sparse-rtrl stream`
//!   subcommand — text lines, JSON-lines and raw binary f32 frames behind
//!   one [`EventFormat`] dispatch, also format-autodetected.
//! * Observability: [`OnlineSession::enable_telemetry`] samples α/β/loss/
//!   op-rate series per session, [`SessionPool::enable_telemetry`]
//!   aggregates evict/admit counters, and
//!   [`SessionPool::telemetry_snapshot`] condenses both into a
//!   [`crate::telemetry::TelemetrySnapshot`]. All of it opt-in and
//!   zero-cost when off (see [`crate::telemetry`]).
//!
//! The batch [`crate::train::Trainer`] is a thin client of
//! [`OnlineSession`] (manual policy + per-minibatch
//! [`OnlineSession::apply_update`]), so the paper experiments and the
//! streaming surface share one code path.

pub mod checkpoint;
pub mod codec;
pub mod events;
pub mod online;
pub mod pool;

pub use checkpoint::SessionCheckpoint;
pub use codec::{CodecError, SnapshotCodec, SnapshotFormat};
pub use events::{
    parse_event, parse_payload, EventError, EventErrorKind, EventFormat, EventPosition,
    EventReader, StreamEvent,
};
pub use online::{OnlineSession, SessionBuilder, StepOutcome, UpdatePolicy};
pub use pool::{BatchStats, PoolError, SessionId, SessionPool};
