//! [`SessionPool`]: N independent [`OnlineSession`]s driven concurrently —
//! the many-users serving scenario.
//!
//! Each session is a user's private learner (own weights, own optimizer
//! moments, own engine state); the pool fans work out over the in-tree
//! worker threads ([`crate::util::pool`]). Sessions are `Send` (the
//! [`crate::rtrl::GradientEngine`] contract requires it), so they migrate
//! freely between workers; results always return in session order.
//!
//! Sessions that share one weight-and-mask set (a fleet of replicas serving
//! the same frozen model, say) can amortize the per-step influence-structure
//! work: [`SessionPool::step_batched`] groups lanes with bitwise-identical
//! parameters and steps each group through one shared-weight
//! [`crate::rtrl::BatchedSparse`] engine, falling back to per-session
//! stepping whenever weights diverge (e.g. right after an update).
//! [`SessionPool::step_batched_runs`] extends the same grouping to runs of
//! consecutive events, amortizing the per-lane state transfer across the
//! run — the serve scheduler's burst path.
//!
//! Idle users need not stay resident: [`SessionPool::evict_id`] spills a
//! session to disk through the snapshot codec facade
//! ([`crate::session::codec`], binary by default) and
//! [`SessionPool::admit_id`] restores it — bit-exactly, in either snapshot
//! format — when the user returns. Sessions are addressed by a stable
//! [`SessionId`] that survives the slot compaction an eviction causes
//! (raw indices shift down); the index-based [`SessionPool::evict`] /
//! [`SessionPool::admit`] API delegates to the id-keyed one. Failures are
//! typed ([`PoolError`]): a long-running server can tell a corrupt spill
//! file ([`PoolError::Codec`]) from a session that simply is not resident
//! ([`PoolError::NoSuchSession`]) without string matching.
//!
//! With [`SessionPool::enable_telemetry`] the evict/admit paths aggregate
//! counters (admissions, evictions, spill bytes) and latency histograms
//! into a [`crate::telemetry::MemoryRecorder`];
//! [`SessionPool::telemetry_snapshot`] condenses them — plus one row per
//! live session — into a serializable
//! [`crate::telemetry::TelemetrySnapshot`].

use super::codec::{self, SnapshotFormat};
use super::online::{OnlineSession, StepOutcome, UpdatePolicy};
use crate::data::StepTarget;
use crate::metrics::OpCounter;
use crate::nn::{Loss, Readout};
use crate::rtrl::{BatchedSparse, EngineState, SparsityMode, Target};
use crate::telemetry::names;
use crate::telemetry::{
    HistogramKind, HistogramSummary, MemoryRecorder, Recorder, SessionStats, TelemetrySnapshot,
};
use crate::util::pool::run_parallel;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
// analyze: allow(ambient-time) -- telemetry latency clocks only; never feeds learner state
use std::time::Instant;

/// Stable identity of a session within one [`SessionPool`], assigned at
/// insertion and never reused. Unlike a slot index, an id stays valid
/// across evictions (which compact the slot array); looking one up after
/// its session was evicted yields [`PoolError::NoSuchSession`] rather than
/// silently addressing a *different* session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(pub u64);

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Typed failure of a pool spill/restore operation, so callers (the serve
/// residency manager foremost) can branch on the failure class instead of
/// string-matching. [`PoolError::Codec`] wraps the snapshot codec's own
/// typed [`CodecError`](codec::CodecError) as its `source`.
#[derive(Debug)]
pub enum PoolError {
    /// The id names no resident session (already evicted, or never
    /// existed in this pool).
    NoSuchSession { id: SessionId },
    /// The slot index is out of range for the current resident set.
    NoSuchIndex { index: usize, len: usize },
    /// Reading or writing the snapshot file failed.
    Io { path: PathBuf, op: &'static str, detail: String },
    /// The spill bytes failed to decode — a corrupt or foreign snapshot.
    Codec { path: PathBuf, source: codec::CodecError },
    /// The checkpoint decoded but refused to resume into a session.
    Resume { path: PathBuf, detail: String },
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::NoSuchSession { id } => write!(f, "no resident session with id {id}"),
            PoolError::NoSuchIndex { index, len } => {
                write!(f, "no session {index} in a pool of {len}")
            }
            PoolError::Io { path, op, detail } => {
                write!(f, "cannot {op} snapshot {}: {detail}", path.display())
            }
            PoolError::Codec { path, source } => {
                write!(f, "corrupt snapshot {}: {source}", path.display())
            }
            PoolError::Resume { path, detail } => {
                write!(f, "snapshot {} cannot resume: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for PoolError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PoolError::Codec { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// What [`SessionPool::step_batched_at`] did with the selected sessions —
/// the per-round batching visibility the serve scheduler reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Shared-weight groups of ≥ 2 lanes that ran fused.
    pub fused_groups: usize,
    /// Lanes stepped through a fused group engine.
    pub fused_lanes: usize,
    /// Sessions stepped per-session (other engine families, singleton
    /// weight groups, or groups that refused state adoption).
    pub solo: usize,
}

/// A fixed set of independent sessions plus a worker-thread budget.
pub struct SessionPool {
    sessions: Vec<OnlineSession>,
    /// Stable id of each slot (parallel to `sessions`).
    ids: Vec<SessionId>,
    /// id → slot lookup; rebuilt incrementally as evictions compact slots.
    slots: BTreeMap<SessionId, usize>,
    next_id: u64,
    workers: usize,
    /// Pool-level aggregation (admissions, evictions, spill bytes, evict/
    /// resume latency). `None` = telemetry off: the evict/admit paths then
    /// skip even their clock reads.
    recorder: Option<MemoryRecorder>,
}

impl SessionPool {
    /// Wrap pre-built sessions. `workers = 0` uses the available hardware
    /// parallelism (the uniform `--threads` semantics of
    /// [`crate::util::pool::resolve_workers`]).
    pub fn new(sessions: Vec<OnlineSession>, workers: usize) -> Self {
        let workers = crate::util::pool::resolve_workers(workers);
        let ids: Vec<SessionId> = (0..sessions.len() as u64).map(SessionId).collect();
        let slots = ids.iter().enumerate().map(|(slot, &id)| (id, slot)).collect();
        let next_id = sessions.len() as u64;
        SessionPool { sessions, ids, slots, next_id, workers, recorder: None }
    }

    /// Append a freshly built session (a tenant arriving for the first
    /// time) and return its stable id.
    pub fn insert(&mut self, session: OnlineSession) -> SessionId {
        let id = SessionId(self.next_id);
        self.next_id += 1;
        self.slots.insert(id, self.sessions.len());
        self.ids.push(id);
        self.sessions.push(session);
        if let Some(rec) = self.recorder.as_mut() {
            rec.gauge(names::POOL_LIVE_SESSIONS, self.sessions.len() as f64);
        }
        id
    }

    /// Stable id of the session currently in slot `i`.
    pub fn id_at(&self, i: usize) -> Option<SessionId> {
        self.ids.get(i).copied()
    }

    /// Current slot of the session with stable id `id`, if resident.
    pub fn slot_of(&self, id: SessionId) -> Option<usize> {
        self.slots.get(&id).copied()
    }

    /// The resident session with stable id `id`.
    pub fn session_by_id(&self, id: SessionId) -> Option<&OnlineSession> {
        self.slot_of(id).map(|i| &self.sessions[i])
    }

    /// Mutable access to the resident session with stable id `id`.
    pub fn session_by_id_mut(&mut self, id: SessionId) -> Option<&mut OnlineSession> {
        let i = self.slot_of(id)?;
        Some(&mut self.sessions[i])
    }

    /// Start aggregating pool-level telemetry (admission/eviction counters,
    /// spill bytes, evict-encode and resume-decode latency histograms).
    /// Counters start from zero at the moment of the call.
    pub fn enable_telemetry(&mut self) {
        self.recorder = Some(MemoryRecorder::new());
    }

    /// Stop aggregating and drop the collected state.
    pub fn disable_telemetry(&mut self) {
        self.recorder = None;
    }

    /// The pool's aggregated recorder, when telemetry is enabled.
    pub fn recorder(&self) -> Option<&MemoryRecorder> {
        self.recorder.as_ref()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn session(&self, i: usize) -> &OnlineSession {
        &self.sessions[i]
    }

    pub fn session_mut(&mut self, i: usize) -> &mut OnlineSession {
        &mut self.sessions[i]
    }

    /// Tear down into the individual sessions (checkpointing each, say).
    pub fn into_sessions(self) -> Vec<OnlineSession> {
        self.sessions
    }

    /// Spill session `i` to `path` in the given snapshot format and drop it
    /// from the pool (later sessions shift down one index; their
    /// [`SessionId`]s are unaffected). Delegates to [`SessionPool::evict_id`].
    pub fn evict(
        &mut self,
        i: usize,
        path: &Path,
        format: SnapshotFormat,
    ) -> Result<(), PoolError> {
        let id =
            self.id_at(i).ok_or(PoolError::NoSuchIndex { index: i, len: self.sessions.len() })?;
        self.evict_id(id, path, format)
    }

    /// Spill the session with stable id `id` to `path` in the given
    /// snapshot format and drop it from the pool. The session is only
    /// removed after the snapshot is durably written, so a failed write
    /// never loses learner state.
    pub fn evict_id(
        &mut self,
        id: SessionId,
        path: &Path,
        format: SnapshotFormat,
    ) -> Result<(), PoolError> {
        let i = self.slot_of(id).ok_or(PoolError::NoSuchSession { id })?;
        // analyze: allow(ambient-time) -- spill-latency metric; encode output is clock-free
        let t0 = self.recorder.as_ref().map(|_| Instant::now());
        let bytes = codec::encode(&self.sessions[i].checkpoint(), format);
        std::fs::write(path, &bytes).map_err(|e| PoolError::Io {
            path: path.to_path_buf(),
            op: "write",
            detail: e.to_string(),
        })?;
        self.sessions.remove(i);
        self.ids.remove(i);
        self.slots.remove(&id);
        for slot in self.slots.values_mut() {
            if *slot > i {
                *slot -= 1;
            }
        }
        if let Some(rec) = self.recorder.as_mut() {
            let ns = t0.map_or(0, |t| t.elapsed().as_nanos() as u64);
            rec.counter(names::POOL_EVICTIONS, 1);
            rec.counter(names::POOL_SPILL_BYTES, bytes.len() as u64);
            rec.observe(names::POOL_EVICT_ENCODE_NS, HistogramKind::LatencyNs, ns);
            rec.observe(names::POOL_SPILL_SIZE_BYTES, HistogramKind::Bytes, bytes.len() as u64);
            rec.gauge(names::POOL_LIVE_SESSIONS, self.sessions.len() as f64);
        }
        Ok(())
    }

    /// Restore a previously evicted session from `path` (either snapshot
    /// format, autodetected) and append it to the pool. Returns the new
    /// session's index. Delegates to [`SessionPool::admit_id`].
    pub fn admit(&mut self, path: &Path) -> Result<usize, PoolError> {
        let id = self.admit_id(path)?;
        // freshly admitted sessions always land in the last slot
        Ok(self.slots[&id])
    }

    /// Restore a previously evicted session from `path` (either snapshot
    /// format, autodetected) and append it to the pool under a **fresh**
    /// stable id, which is returned. Resumption is bit-exact: the
    /// readmitted learner continues its stream as if it had never left
    /// memory. (Runtime knobs — threads, telemetry — are not snapshot
    /// state; re-apply them on the readmitted session if needed.)
    pub fn admit_id(&mut self, path: &Path) -> Result<SessionId, PoolError> {
        // analyze: allow(ambient-time) -- admit-latency metric; decode output is clock-free
        let t0 = self.recorder.as_ref().map(|_| Instant::now());
        let bytes = std::fs::read(path).map_err(|e| PoolError::Io {
            path: path.to_path_buf(),
            op: "read",
            detail: e.to_string(),
        })?;
        let ck = codec::decode(&bytes)
            .map_err(|source| PoolError::Codec { path: path.to_path_buf(), source })?;
        let session = OnlineSession::resume(&ck)
            .map_err(|detail| PoolError::Resume { path: path.to_path_buf(), detail })?;
        let id = self.insert(session);
        if let Some(rec) = self.recorder.as_mut() {
            let ns = t0.map_or(0, |t| t.elapsed().as_nanos() as u64);
            rec.counter(names::POOL_ADMISSIONS, 1);
            rec.observe(names::POOL_RESUME_DECODE_NS, HistogramKind::LatencyNs, ns);
        }
        Ok(id)
    }

    /// Condense the pool's aggregated telemetry plus one row per live
    /// session into a serializable [`TelemetrySnapshot`]. Works with
    /// telemetry disabled too (all pool counters read zero); per-session
    /// α/β/loss columns fill in only for sessions whose own telemetry is
    /// on ([`OnlineSession::enable_telemetry`]).
    pub fn telemetry_snapshot(&self) -> TelemetrySnapshot {
        let (admissions, evictions, spill_bytes, evict_ns, resume_ns) = match &self.recorder {
            Some(r) => (
                r.counter_value(names::POOL_ADMISSIONS),
                r.counter_value(names::POOL_EVICTIONS),
                r.counter_value(names::POOL_SPILL_BYTES),
                r.histogram(names::POOL_EVICT_ENCODE_NS)
                    .map(HistogramSummary::from_histogram)
                    .unwrap_or_default(),
                r.histogram(names::POOL_RESUME_DECODE_NS)
                    .map(HistogramSummary::from_histogram)
                    .unwrap_or_default(),
            ),
            None => (0, 0, 0, HistogramSummary::default(), HistogramSummary::default()),
        };
        let sessions = self
            .sessions
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let latest = s.telemetry().and_then(|t| t.latest_point());
                SessionStats {
                    index: i as u64,
                    steps: s.steps(),
                    supervised_steps: s.supervised_steps(),
                    updates_applied: s.updates_applied(),
                    loss_ewma: s.telemetry().and_then(|t| t.loss_ewma()),
                    alpha: latest.map(|p| p.alpha),
                    beta: latest.map(|p| p.beta),
                    points: s.telemetry().map_or(0, |t| t.points().count() as u64),
                }
            })
            .collect();
        TelemetrySnapshot {
            live_sessions: self.sessions.len() as u64,
            workers: self.workers as u64,
            admissions,
            evictions,
            spill_bytes,
            evict_encode_ns: evict_ns,
            resume_decode_ns: resume_ns,
            sessions,
        }
    }

    /// Deliver one event per session (index-aligned) and step them all
    /// concurrently. Outcomes return in session order.
    pub fn step_all(&mut self, events: &[(Vec<f32>, StepTarget)]) -> Vec<StepOutcome> {
        assert_eq!(events.len(), self.sessions.len(), "one event per session");
        self.run_each(|i, s| {
            let (x, t) = &events[i];
            s.step(x, t.as_target())
        })
    }

    /// Deliver one event per session like [`SessionPool::step_all`], but
    /// step sessions that share one weight-and-mask set through a single
    /// shared-weight [`BatchedSparse`] engine, building each step's
    /// influence structure once per group instead of once per session.
    ///
    /// Grouping is exact, not heuristic: two sessions batch together only
    /// when both run the parameter-mode sparse engine
    /// ([`SparsityMode::Parameter`]) and their stacks agree bitwise —
    /// same shape, same cell dynamics/activation/thresholds, same mask
    /// pattern, same parameter bits — and their readouts have the same
    /// width. Everything else (other engines, singleton groups, lanes whose
    /// engine state cannot be adopted into the group — a fresh lane joining
    /// a mid-sequence group, say) steps per-session exactly as
    /// [`SessionPool::step_all`] would.
    ///
    /// Sessions keep full ownership of their own learning state: each lane
    /// is loaded into the group engine from `engine.save_state()`, stepped,
    /// and written back via `load_state` — so outcomes, op charges and
    /// update-policy behaviour are per-session, and an update applied by one
    /// lane diverges its weights so the *next* call regroups around it.
    /// Batched groups use the group leader's thread knob for the fused
    /// panel update; influence measurement is on for a group when any lane's
    /// telemetry requests it.
    ///
    /// Unlike `step_all`, sessions do not migrate to worker threads here —
    /// parallelism comes from inside the fused step (`step_all` remains the
    /// concurrent path for independently-weighted pools). Outcomes return
    /// in session order.
    pub fn step_batched(&mut self, events: &[(Vec<f32>, StepTarget)]) -> Vec<StepOutcome> {
        assert_eq!(events.len(), self.sessions.len(), "one event per session");
        let slots: Vec<usize> = (0..self.sessions.len()).collect();
        self.step_batched_at(&slots, events).0
    }

    /// Step only the sessions in `slots` (strictly increasing slot
    /// indices), each paired with the event at the same position in
    /// `events`, with the exact shared-weight grouping of
    /// [`SessionPool::step_batched`]. The serve scheduler's entry point: a
    /// round only has events for *ready* tenants, not the whole pool.
    /// Outcomes return in `slots` order, alongside [`BatchStats`] saying
    /// how many lanes actually fused.
    pub fn step_batched_at(
        &mut self,
        slots: &[usize],
        events: &[(Vec<f32>, StepTarget)],
    ) -> (Vec<StepOutcome>, BatchStats) {
        assert_eq!(events.len(), slots.len(), "one event per selected slot");
        let n = self.sessions.len();
        for w in slots.windows(2) {
            assert!(w[0] < w[1], "slots must be strictly increasing");
        }
        if let Some(&last) = slots.last() {
            assert!(last < n, "slot {last} out of range for a pool of {n}");
        }

        // Group selected sessions by exact weight identity, recording each
        // member as (slot, position in `slots`/`events`). Ascending slot
        // order within each group, so lane order matches a forward
        // iter_mut scan.
        let mut selected: Vec<Option<usize>> = vec![None; n];
        for (pos, &i) in slots.iter().enumerate() {
            selected[i] = Some(pos);
        }
        let mut groups: Vec<(Vec<u64>, Vec<(usize, usize)>)> = Vec::new();
        for (i, s) in self.sessions.iter_mut().enumerate() {
            let Some(pos) = selected[i] else { continue };
            if let Some(k) = shared_weight_key(s) {
                match groups.iter_mut().find(|(gk, _)| *gk == k) {
                    Some((_, g)) => g.push((i, pos)),
                    None => groups.push((k, vec![(i, pos)])),
                }
            }
        }

        let mut stats = BatchStats::default();
        let mut outcomes: Vec<Option<StepOutcome>> = (0..slots.len()).map(|_| None).collect();
        for (_, group) in groups.iter().filter(|(_, g)| g.len() >= 2) {
            let lanes = group.len();
            let mut batched = {
                let leader = &self.sessions[group[0].0];
                let mut b = BatchedSparse::new(leader.net(), leader.n_out(), lanes);
                b.set_threads(leader.threads);
                let measure = group.iter().any(|&(i, _)| {
                    self.sessions[i]
                        .telemetry()
                        .is_some_and(|t| t.config().measure_influence)
                });
                b.set_measure_influence(measure);
                b
            };

            // Adopt every lane's engine state. Any refusal (a lane whose
            // panel activity disagrees with the group's, say) sends the
            // whole group down the per-session path — correctness first.
            let adopted = group.iter().enumerate().all(|(lane, &(i, _))| {
                let st = self.sessions[i].engine.save_state();
                batched.load_lane(lane, &st).is_ok()
            });
            if !adopted {
                continue;
            }

            // Pass A: borrow each lane's per-session pieces (readout, loss,
            // op counter) side by side and run the fused step.
            let mut xs: Vec<&[f32]> = Vec::with_capacity(lanes);
            let mut targets: Vec<Target<'_>> = Vec::with_capacity(lanes);
            let mut readouts: Vec<&mut Readout> = Vec::with_capacity(lanes);
            let mut losses: Vec<&mut Loss> = Vec::with_capacity(lanes);
            let mut opsv: Vec<&mut OpCounter> = Vec::with_capacity(lanes);
            // analyze: allow(ambient-time) -- per-lane step-latency clocks (telemetry only)
            let mut t0s: Vec<Option<Instant>> = Vec::with_capacity(lanes);
            let mut next_member = 0usize;
            for (i, s) in self.sessions.iter_mut().enumerate() {
                if next_member == lanes || group[next_member].0 != i {
                    continue;
                }
                let pos = group[next_member].1;
                next_member += 1;
                assert_eq!(events[pos].0.len(), s.net.n_in(), "input width must match the stack");
                // analyze: allow(ambient-time) -- read only when telemetry is on; bit-identity pinned by tests
                t0s.push(if s.telemetry.is_some() { Some(Instant::now()) } else { None });
                let OnlineSession { readout, loss, ops, .. } = s;
                readouts.push(readout);
                losses.push(loss);
                opsv.push(ops);
                xs.push(&events[pos].0);
                targets.push(events[pos].1.as_target());
            }
            let results = batched.step(&xs, &targets, &mut readouts, &mut losses, &mut opsv);

            // Pass B: hand each lane its post-step engine state back, then
            // run the ordinary per-session bookkeeping (serving-mode
            // prediction, update policy, telemetry). An update applied here
            // diverges that lane's weights; the next call regroups.
            for (lane, &(i, pos)) in group.iter().enumerate() {
                let st = batched.save_lane(lane);
                let s = &mut self.sessions[i];
                adopt_back(s, &st);
                outcomes[pos] = Some(s.absorb_step_result(results[lane], t0s[lane]));
            }
            stats.fused_groups += 1;
            stats.fused_lanes += lanes;
        }

        // Everyone else — other engine families, singleton weight groups,
        // groups that refused adoption — steps per-session, in slot order.
        for (pos, &i) in slots.iter().enumerate() {
            if outcomes[pos].is_none() {
                let (x, t) = &events[pos];
                outcomes[pos] = Some(self.sessions[i].step(x, t.as_target()));
                stats.solo += 1;
            }
        }
        let outs =
            outcomes.into_iter().map(|o| o.expect("every selected session stepped")).collect();
        (outs, stats)
    }

    /// Step the sessions in `slots` through **runs** of consecutive events —
    /// `runs[j]` holds the next `k` events for the session in `slots[j]`,
    /// every run the same length `k ≥ 1` — amortizing the per-call lane
    /// state transfer of [`SessionPool::step_batched_at`] across the whole
    /// run. A fused group loads each lane into the shared-weight engine
    /// once, steps it `k` times (per-step bookkeeping — serving-mode
    /// predictions, counters, telemetry — still runs every sub-step,
    /// reading activations straight from the group engine), and writes each
    /// lane back once at the end of the run. At `k = 1` this *is*
    /// [`SessionPool::step_batched_at`].
    ///
    /// Deferring the write-back is only sound when no lane can apply a
    /// parameter update mid-run: an update harvests the *session* engine,
    /// which holds pre-run state until the write-back. A group therefore
    /// fuses a run only when every lane's policy provably cannot fire
    /// during it — [`UpdatePolicy::Manual`] and
    /// [`UpdatePolicy::EndOfSequence`] never fire on a step, and
    /// [`UpdatePolicy::EveryKSteps`] cannot fire while the lane's pending
    /// supervised count plus the run's supervised events stays below the
    /// cadence. Groups failing the check (and singleton groups, other
    /// engines, refused adoptions) step per-session, event by event,
    /// exactly as [`SessionPool::step_all`] would.
    ///
    /// Outcomes return in `slots` order, `k` per session, alongside
    /// [`BatchStats`] counting each *lane* once per call (not once per
    /// sub-step).
    pub fn step_batched_runs(
        &mut self,
        slots: &[usize],
        runs: &[Vec<(Vec<f32>, StepTarget)>],
    ) -> (Vec<Vec<StepOutcome>>, BatchStats) {
        assert_eq!(runs.len(), slots.len(), "one run per selected slot");
        let k = runs.first().map_or(1, Vec::len);
        assert!(k >= 1, "runs must hold at least one event");
        for r in runs {
            assert_eq!(r.len(), k, "all runs must have the same length");
        }
        if k == 1 {
            let events: Vec<(Vec<f32>, StepTarget)> = runs.iter().map(|r| r[0].clone()).collect();
            let (outs, stats) = self.step_batched_at(slots, &events);
            return (outs.into_iter().map(|o| vec![o]).collect(), stats);
        }
        let n = self.sessions.len();
        for w in slots.windows(2) {
            assert!(w[0] < w[1], "slots must be strictly increasing");
        }
        if let Some(&last) = slots.last() {
            assert!(last < n, "slot {last} out of range for a pool of {n}");
        }

        let mut selected: Vec<Option<usize>> = vec![None; n];
        for (pos, &i) in slots.iter().enumerate() {
            selected[i] = Some(pos);
        }
        let mut groups: Vec<(Vec<u64>, Vec<(usize, usize)>)> = Vec::new();
        for (i, s) in self.sessions.iter_mut().enumerate() {
            let Some(pos) = selected[i] else { continue };
            if let Some(key) = shared_weight_key(s) {
                match groups.iter_mut().find(|(gk, _)| *gk == key) {
                    Some((_, g)) => g.push((i, pos)),
                    None => groups.push((key, vec![(i, pos)])),
                }
            }
        }

        let mut stats = BatchStats::default();
        let mut outcomes: Vec<Vec<StepOutcome>> =
            (0..slots.len()).map(|_| Vec::with_capacity(k)).collect();
        for (_, group) in groups.iter().filter(|(_, g)| g.len() >= 2) {
            if !group.iter().all(|&(i, pos)| run_fuses(&self.sessions[i], &runs[pos])) {
                continue;
            }
            let lanes = group.len();
            let mut batched = {
                let leader = &self.sessions[group[0].0];
                let mut b = BatchedSparse::new(leader.net(), leader.n_out(), lanes);
                b.set_threads(leader.threads);
                let measure = group.iter().any(|&(i, _)| {
                    self.sessions[i]
                        .telemetry()
                        .is_some_and(|t| t.config().measure_influence)
                });
                b.set_measure_influence(measure);
                b
            };
            let adopted = group.iter().enumerate().all(|(lane, &(i, _))| {
                let st = self.sessions[i].engine.save_state();
                batched.load_lane(lane, &st).is_ok()
            });
            if !adopted {
                continue;
            }

            for t in 0..k {
                let mut xs: Vec<&[f32]> = Vec::with_capacity(lanes);
                let mut targets: Vec<Target<'_>> = Vec::with_capacity(lanes);
                let mut readouts: Vec<&mut Readout> = Vec::with_capacity(lanes);
                let mut losses: Vec<&mut Loss> = Vec::with_capacity(lanes);
                let mut opsv: Vec<&mut OpCounter> = Vec::with_capacity(lanes);
                // analyze: allow(ambient-time) -- per-lane step-latency clocks (telemetry only)
                let mut t0s: Vec<Option<Instant>> = Vec::with_capacity(lanes);
                let mut next_member = 0usize;
                for (i, s) in self.sessions.iter_mut().enumerate() {
                    if next_member == lanes || group[next_member].0 != i {
                        continue;
                    }
                    let pos = group[next_member].1;
                    next_member += 1;
                    let (x, tgt) = &runs[pos][t];
                    assert_eq!(x.len(), s.net.n_in(), "input width must match the stack");
                    // analyze: allow(ambient-time) -- read only when telemetry is on; bit-identity pinned by tests
                    t0s.push(if s.telemetry.is_some() { Some(Instant::now()) } else { None });
                    let OnlineSession { readout, loss, ops, .. } = s;
                    readouts.push(readout);
                    losses.push(loss);
                    opsv.push(ops);
                    xs.push(x);
                    targets.push(tgt.as_target());
                }
                let results = batched.step(&xs, &targets, &mut readouts, &mut losses, &mut opsv);
                for (lane, &(i, pos)) in group.iter().enumerate() {
                    let out = self.sessions[i].absorb_step_result_from(
                        results[lane],
                        t0s[lane],
                        Some(batched.activations(lane)),
                    );
                    outcomes[pos].push(out);
                }
            }
            for (lane, &(i, _)) in group.iter().enumerate() {
                let st = batched.save_lane(lane);
                adopt_back(&mut self.sessions[i], &st);
            }
            stats.fused_groups += 1;
            stats.fused_lanes += lanes;
        }

        // Everyone else — ineligible or refused groups, singleton weight
        // groups, other engine families — steps per-session, in slot order.
        for (pos, &i) in slots.iter().enumerate() {
            if outcomes[pos].is_empty() {
                for (x, tgt) in &runs[pos] {
                    let out = self.sessions[i].step(x, tgt.as_target());
                    outcomes[pos].push(out);
                }
                stats.solo += 1;
            }
        }
        (outcomes, stats)
    }

    /// Run an arbitrary closure over every session concurrently (e.g. drain
    /// a per-user event queue); results return in session order. The
    /// sessions move to worker threads for the duration of the call.
    ///
    /// Failure containment: a panic in `f` for one session is caught at
    /// that session's boundary — every sibling still runs, **all** sessions
    /// (including the panicked one, whose learning state may be mid-step)
    /// return to the pool, and only then is the first panic re-raised.
    pub fn run_each<R, F>(&mut self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &mut OnlineSession) -> R + Sync,
    {
        use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
        let sessions = std::mem::take(&mut self.sessions);
        let results = run_parallel(sessions, self.workers, |i, mut s| {
            let r = catch_unwind(AssertUnwindSafe(|| f(i, &mut s)));
            (s, r)
        });
        let mut out = Vec::with_capacity(results.len());
        let mut first_panic = None;
        for (s, r) in results {
            self.sessions.push(s);
            match r {
                Ok(r) => out.push(r),
                Err(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
            }
        }
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
        out
    }
}

/// Hand a lane's post-step engine state back to its session — the
/// write-back half of the batched-lane round trip.
fn adopt_back(s: &mut OnlineSession, st: &EngineState) {
    let OnlineSession { engine, net, .. } = &mut *s;
    engine
        .load_state(net, st)
        .expect("a batched lane state always round-trips into its own engine");
}

/// The run-fusion soundness condition of
/// [`SessionPool::step_batched_runs`]: can this lane's per-step bookkeeping
/// run once per event in `run` without a parameter update firing?
fn run_fuses(s: &OnlineSession, run: &[(Vec<f32>, StepTarget)]) -> bool {
    match s.policy {
        UpdatePolicy::Manual | UpdatePolicy::EndOfSequence => true,
        UpdatePolicy::EveryKSteps(k) => {
            let supervised =
                run.iter().filter(|(_, t)| !matches!(t, StepTarget::None)).count() as u64;
            s.pending_supervised + supervised < k
        }
    }
}

/// Exact batchability fingerprint for [`SessionPool::step_batched`]:
/// `Some(key)` iff the session runs the parameter-mode sparse engine, where
/// equal keys guarantee bitwise-identical forward/Jacobian arithmetic —
/// stack shape, cell dynamics and activation (with γ/ε bits), threshold
/// bits, parameter bits, kept-column structure (the mask), and readout
/// width all participate. `None` marks the session per-session-only.
fn shared_weight_key(s: &mut OnlineSession) -> Option<Vec<u64>> {
    use crate::nn::{Activation, Dynamics};
    let parameter_mode =
        matches!(s.engine.as_sparse().map(|e| e.mode()), Some(SparsityMode::Parameter));
    if !parameter_mode {
        return None;
    }
    let net = s.net();
    let mut key = Vec::new();
    key.push(net.layers() as u64);
    key.push(s.n_out() as u64);
    for l in 0..net.layers() {
        let c = net.layer(l);
        key.push(c.n() as u64);
        key.push(c.n_in() as u64);
        key.push(match c.dynamics() {
            Dynamics::Linear => 0,
            Dynamics::Gated => 1,
        });
        match c.activation() {
            Activation::Heaviside { gamma, eps } => {
                key.push(2);
                key.push(gamma.to_bits() as u64);
                key.push(eps.to_bits() as u64);
            }
            Activation::Tanh => key.push(3),
        }
        key.extend(c.theta().iter().map(|v| v.to_bits() as u64));
        key.push(c.params().len() as u64);
        key.extend(c.params().iter().map(|v| v.to_bits() as u64));
        for k in 0..c.n() {
            let cols = c.kept_cols(k);
            key.push(cols.len() as u64);
            key.extend(cols.iter().map(|&x| x as u64));
        }
    }
    Some(key)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, ExperimentConfig};
    use crate::rtrl::Target;
    use crate::session::{SessionBuilder, UpdatePolicy};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn make_pool(n: usize, workers: usize) -> SessionPool {
        let sessions = (0..n)
            .map(|i| {
                let mut cfg = ExperimentConfig::default();
                cfg.model.hidden = 6;
                cfg.seed = 100 + i as u64; // every user gets their own weights
                SessionBuilder::from_config(cfg)
                    .algorithm(AlgorithmKind::RtrlBoth)
                    .policy(UpdatePolicy::EveryKSteps(1))
                    .build()
            })
            .collect();
        SessionPool::new(sessions, workers)
    }

    /// ≥ 8 sessions stepping concurrently, many rounds, each learning its
    /// own stream — the acceptance bar for the many-users scenario.
    #[test]
    fn eight_concurrent_sessions_sustain_independent_streams() {
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let mut pool = make_pool(8, 8);
        for round in 0..30 {
            pool.run_each(|i, s| {
                let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
                PEAK.fetch_max(c, Ordering::SeqCst);
                // hold the slot briefly so overlap is observable even though
                // a single step only takes microseconds
                std::thread::sleep(std::time::Duration::from_millis(2));
                let x = [(round as f32 * 0.3 + i as f32).sin(), 0.5];
                let t = if round % 3 == 0 { Target::Class(i % 2) } else { Target::None };
                let o = s.step(&x, t);
                CUR.fetch_sub(1, Ordering::SeqCst);
                o
            });
        }
        assert!(PEAK.load(Ordering::SeqCst) >= 2, "sessions never overlapped");
        for i in 0..pool.len() {
            assert_eq!(pool.session(i).steps(), 30);
            assert_eq!(pool.session(i).supervised_steps(), 10);
            assert_eq!(pool.session(i).updates_applied(), 10);
        }
        // independent learners: different seeds → different weights
        let mut p0 = vec![0.0; pool.session(0).net().p()];
        let mut p1 = vec![0.0; pool.session(1).net().p()];
        pool.session(0).net().copy_params_into(&mut p0);
        pool.session(1).net().copy_params_into(&mut p1);
        assert_ne!(p0, p1);
    }

    /// One user's panic must not destroy the other users' learned state:
    /// after a contained panic, every session (including the offender) is
    /// still in the pool and the siblings' steps were applied.
    #[test]
    fn one_panicking_session_does_not_lose_the_others() {
        let mut pool = make_pool(6, 3);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_each(|i, s| {
                if i == 2 {
                    panic!("user 2 sent a poison event");
                }
                s.step(&[0.4, -0.4], Target::Class(i % 2))
            })
        }));
        assert!(caught.is_err(), "the panic must still surface");
        assert_eq!(pool.len(), 6, "sessions were lost from the pool");
        for i in 0..6 {
            let expect = if i == 2 { 0 } else { 1 };
            assert_eq!(pool.session(i).steps(), expect, "session {i} step count");
        }
        // the pool remains fully usable afterwards
        let outs = pool.run_each(|_, s| s.step(&[0.1, 0.2], Target::None));
        assert_eq!(outs.len(), 6);
    }

    /// `step_all` preserves session order and pairs events by index.
    #[test]
    fn step_all_is_index_aligned() {
        let mut pool = make_pool(4, 2);
        let events: Vec<(Vec<f32>, StepTarget)> = (0..4)
            .map(|i| (vec![i as f32, -1.0], StepTarget::Class(i % 2)))
            .collect();
        let outs = pool.step_all(&events);
        assert_eq!(outs.len(), 4);
        for o in &outs {
            assert_eq!(o.step, 1);
            assert!(o.loss.is_some());
        }
    }

    /// Evict a session to disk (binary snapshot), admit it back, and the
    /// readmitted learner produces bit-identical outcomes to a twin that
    /// never left memory.
    #[test]
    fn evict_admit_round_trip_is_bit_exact() {
        let dir = std::env::temp_dir()
            .join(format!("sparse-rtrl-pool-evict-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spill = dir.join("user1.snap");

        let mut pool = make_pool(3, 2);
        for round in 0..5 {
            pool.run_each(|i, s| {
                s.step(&[(i + round) as f32 * 0.2, -0.3], Target::Class((i + round) % 2))
            });
        }
        // twin of session 1 that stays resident
        let twin_ck = pool.session(1).checkpoint();
        let mut twin = OnlineSession::resume(&twin_ck).unwrap();

        pool.evict(1, &spill, SnapshotFormat::Binary).unwrap();
        assert_eq!(pool.len(), 2);
        assert!(pool.evict(7, &spill, SnapshotFormat::Binary).is_err());

        let idx = pool.admit(&spill).unwrap();
        assert_eq!((pool.len(), idx), (3, 2), "readmitted at the end");
        let back = pool.session_mut(idx);
        for round in 0..4 {
            let a = back.step(&[0.7, -0.1 * round as f32], Target::Class(round % 2));
            let b = twin.step(&[0.7, -0.1 * round as f32], Target::Class(round % 2));
            assert_eq!(a.loss.map(f32::to_bits), b.loss.map(f32::to_bits), "round {round}");
            assert_eq!(a.prediction, b.prediction);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Pool telemetry observes the evict/admit lifecycle: counters, spill
    /// bytes and latency histograms all move, and the snapshot serializes
    /// round-trip through its JSON form.
    #[test]
    fn telemetry_counts_evictions_and_admissions() {
        let dir = std::env::temp_dir()
            .join(format!("sparse-rtrl-pool-telemetry-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spill = dir.join("user0.snap");

        let mut pool = make_pool(2, 1);
        // disabled pool telemetry still snapshots (zero counters)
        let cold = pool.telemetry_snapshot();
        assert_eq!((cold.evictions, cold.admissions, cold.live_sessions), (0, 0, 2));

        pool.enable_telemetry();
        pool.run_each(|i, s| s.step(&[0.2, -0.2], Target::Class(i % 2)));
        pool.evict(0, &spill, SnapshotFormat::Binary).unwrap();
        let idx = pool.admit(&spill).unwrap();
        assert_eq!(idx, 1);

        let snap = pool.telemetry_snapshot();
        assert_eq!(snap.evictions, 1);
        assert_eq!(snap.admissions, 1);
        assert_eq!(snap.live_sessions, 2);
        assert_eq!(snap.spill_bytes, std::fs::metadata(&spill).unwrap().len());
        assert_eq!(snap.evict_encode_ns.count, 1);
        assert_eq!(snap.resume_decode_ns.count, 1);
        assert!(snap.resume_decode_ns.max > 0);
        let rec = pool.recorder().unwrap();
        assert_eq!(
            rec.gauge_value(crate::telemetry::names::POOL_LIVE_SESSIONS),
            Some(2.0)
        );

        let back = TelemetrySnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `n` replicas of ONE parameter-mode learner: same seed → bitwise the
    /// same weights and mask, so [`SessionPool::step_batched`] can fuse
    /// them into a single shared-weight group.
    fn make_shared_pool(n: usize, seed: u64, policy: UpdatePolicy, threads: usize) -> SessionPool {
        let sessions = (0..n)
            .map(|_| {
                let mut cfg = ExperimentConfig::default();
                cfg.model.hidden = 6;
                cfg.seed = seed;
                SessionBuilder::from_config(cfg)
                    .algorithm(AlgorithmKind::RtrlParam)
                    .param_sparsity(0.5)
                    .policy(policy)
                    .threads(threads)
                    .build()
            })
            .collect();
        SessionPool::new(sessions, 2)
    }

    fn shared_events(pool_len: usize, round: usize) -> Vec<(Vec<f32>, StepTarget)> {
        (0..pool_len)
            .map(|i| {
                let x = vec![(round as f32 * 0.4 + i as f32).sin(), 0.3 - 0.1 * i as f32];
                let t = if round % 3 == 0 {
                    StepTarget::Class((i + round) % 2)
                } else {
                    StepTarget::None
                };
                (x, t)
            })
            .collect()
    }

    /// Batched stepping is a pure execution strategy: a shared-weight pool
    /// driven by `step_batched` tracks a twin pool driven by `step_all`
    /// step for step (losses agree to float tolerance — the solo engine
    /// compresses exact structural zeros out of its row lists, so the two
    /// paths sum in slightly different orders; predictions, counts and
    /// policy behaviour agree exactly).
    #[test]
    fn step_batched_matches_per_session_stepping() {
        let mut fused = make_shared_pool(4, 42, UpdatePolicy::Manual, 1);
        let mut solo = make_shared_pool(4, 42, UpdatePolicy::Manual, 1);
        // the replicas really are one weight set: every pair of keys agrees
        let keys: Vec<_> =
            (0..4).map(|i| shared_weight_key(fused.session_mut(i)).unwrap()).collect();
        assert!(keys.iter().all(|k| *k == keys[0]), "replicas must share a weight key");

        for round in 0..9 {
            let events = shared_events(4, round);
            let a = fused.step_batched(&events);
            let b = solo.step_all(&events);
            for i in 0..4 {
                match (a[i].loss, b[i].loss) {
                    (Some(x), Some(y)) => {
                        assert!(
                            (x - y).abs() <= 1e-5 * (1.0 + y.abs()),
                            "round {round} session {i}: batched loss {x} vs solo {y}"
                        );
                    }
                    (x, y) => assert_eq!(x, y, "round {round} session {i} supervision"),
                }
                assert_eq!(a[i].prediction, b[i].prediction, "round {round} session {i}");
                assert_eq!(a[i].step, b[i].step);
            }
        }
        for i in 0..4 {
            assert_eq!(fused.session(i).steps(), 9);
            assert_eq!(fused.session(i).supervised_steps(), 3);
            assert_eq!(fused.session(i).updates_applied(), 0, "Manual policy never applies");
        }
    }

    /// The batched path is bit-identical at any intra-step thread count —
    /// the same contract the solo engines pin, surfaced at pool level.
    #[test]
    fn step_batched_outcomes_independent_of_thread_knob() {
        let run = |threads: usize| -> Vec<Vec<Option<u32>>> {
            let mut pool = make_shared_pool(3, 7, UpdatePolicy::Manual, threads);
            (0..8)
                .map(|round| {
                    let outs = pool.step_batched(&shared_events(3, round));
                    outs.iter().map(|o| o.loss.map(f32::to_bits)).collect()
                })
                .collect()
        };
        assert_eq!(run(1), run(3), "thread knob changed batched results");
    }

    /// An update applied by a lane (EveryKSteps(1)) diverges its weights
    /// from the group; the next `step_batched` call must regroup — here
    /// every lane updates on a *different* gradient, so all keys split and
    /// every session falls back to per-session stepping, still correctly.
    #[test]
    fn step_batched_regroups_after_update_divergence() {
        let mut pool = make_shared_pool(3, 11, UpdatePolicy::EveryKSteps(1), 1);
        // round 0: supervised with per-lane inputs/targets → per-lane updates
        let events: Vec<(Vec<f32>, StepTarget)> = (0..3)
            .map(|i| (vec![0.9 - 0.4 * i as f32, -0.2], StepTarget::Class(i % 2)))
            .collect();
        let outs = pool.step_batched(&events);
        assert!(outs.iter().all(|o| o.loss.is_some()));
        for i in 0..3 {
            assert_eq!(pool.session(i).updates_applied(), 1, "lane {i} must have updated");
        }
        let keys: Vec<_> =
            (0..3).map(|i| shared_weight_key(pool.session_mut(i)).unwrap()).collect();
        assert!(keys[0] != keys[1] && keys[1] != keys[2] && keys[0] != keys[2],
            "independent updates must diverge the weight keys");
        // later rounds run on the fallback path and keep learning
        for round in 1..4 {
            let outs = pool.step_batched(&shared_events(3, round * 3));
            assert_eq!(outs.len(), 3);
            assert!(outs.iter().all(|o| o.loss.is_some()));
        }
        for i in 0..3 {
            assert_eq!(pool.session(i).steps(), 4);
            assert_eq!(pool.session(i).updates_applied(), 4);
        }
    }

    /// A mixed pool — a shared-weight pair, an unbatchable engine family,
    /// and a parameter-mode singleton — steps everyone, in session order.
    #[test]
    fn step_batched_mixes_batchable_and_solo_engines() {
        let mut sessions = Vec::new();
        for (alg, seed) in [
            (AlgorithmKind::RtrlParam, 7u64),
            (AlgorithmKind::RtrlBoth, 7),
            (AlgorithmKind::RtrlParam, 7),
            (AlgorithmKind::RtrlParam, 9),
        ] {
            let mut cfg = ExperimentConfig::default();
            cfg.model.hidden = 6;
            cfg.seed = seed;
            sessions.push(
                SessionBuilder::from_config(cfg)
                    .algorithm(alg)
                    .param_sparsity(0.5)
                    .policy(UpdatePolicy::Manual)
                    .build(),
            );
        }
        let mut pool = SessionPool::new(sessions, 2);
        assert_eq!(
            shared_weight_key(pool.session_mut(0)),
            shared_weight_key(pool.session_mut(2)),
            "same seed + same algorithm must share a key"
        );
        assert_eq!(shared_weight_key(pool.session_mut(1)), None, "RtrlBoth is per-session-only");
        assert_ne!(
            shared_weight_key(pool.session_mut(0)),
            shared_weight_key(pool.session_mut(3)),
            "different seeds must not group"
        );
        for round in 0..6 {
            let outs = pool.step_batched(&shared_events(4, round));
            assert_eq!(outs.len(), 4);
        }
        for i in 0..4 {
            assert_eq!(pool.session(i).steps(), 6, "session {i} must step every round");
        }
    }

    /// Pool results are deterministic regardless of worker interleaving: a
    /// 1-worker pool and an 8-worker pool produce identical per-session
    /// outcomes.
    #[test]
    fn outcomes_independent_of_worker_count() {
        let run = |workers: usize| -> Vec<Vec<u32>> {
            let mut pool = make_pool(6, workers);
            let mut all = Vec::new();
            for round in 0..10 {
                let outs = pool.run_each(|i, s| {
                    let x = [(i as f32 - round as f32).cos(), 0.1];
                    s.step(&x, Target::Class((i + round) % 2))
                });
                all.push(outs.iter().map(|o| o.loss.unwrap().to_bits()).collect());
            }
            all
        };
        assert_eq!(run(1), run(8));
    }

    /// A run-fused pool (`step_batched_runs`: one lane load/save per run)
    /// is bit-identical to per-event batched stepping (`step_batched`: one
    /// lane load/save per step) — the state round trip is exact, so
    /// deferring the write-back cannot change the math, and serving-mode
    /// predictions read the group engine's activations correctly.
    #[test]
    fn step_batched_runs_matches_per_event_batched_bitwise() {
        let build = || {
            let sessions = (0..3)
                .map(|_| {
                    let mut cfg = ExperimentConfig::default();
                    cfg.model.hidden = 6;
                    cfg.seed = 21;
                    SessionBuilder::from_config(cfg)
                        .algorithm(AlgorithmKind::RtrlParam)
                        .param_sparsity(0.5)
                        .policy(UpdatePolicy::Manual)
                        .predict_always(true)
                        .build()
                })
                .collect();
            SessionPool::new(sessions, 2)
        };
        let mut by_runs = build();
        let mut by_event = build();
        let k = 4usize;
        let slots = [0usize, 1, 2];
        for round in 0..3 {
            let runs: Vec<Vec<(Vec<f32>, StepTarget)>> = (0..3)
                .map(|i| {
                    (0..k)
                        .map(|t| {
                            let x = vec![
                                ((round * k + t) as f32 * 0.3 + i as f32).sin(),
                                0.2 - 0.1 * i as f32,
                            ];
                            let tgt = if (t + i) % 3 == 0 {
                                StepTarget::Class((i + t) % 2)
                            } else {
                                StepTarget::None
                            };
                            (x, tgt)
                        })
                        .collect()
                })
                .collect();
            let (outs, stats) = by_runs.step_batched_runs(&slots, &runs);
            assert_eq!(stats, BatchStats { fused_groups: 1, fused_lanes: 3, solo: 0 });
            for t in 0..k {
                let events: Vec<(Vec<f32>, StepTarget)> =
                    (0..3).map(|i| runs[i][t].clone()).collect();
                let ref_outs = by_event.step_batched(&events);
                for i in 0..3 {
                    let (a, b) = (&outs[i][t], &ref_outs[i]);
                    assert_eq!(a.step, b.step);
                    assert_eq!(
                        a.loss.map(f32::to_bits),
                        b.loss.map(f32::to_bits),
                        "round {round} lane {i} sub-step {t}"
                    );
                    assert_eq!(a.prediction, b.prediction, "round {round} lane {i} sub-step {t}");
                    assert_eq!(a.updated, b.updated);
                }
            }
        }
        for i in 0..3 {
            assert_eq!(by_runs.session(i).steps(), 12);
            assert_eq!(by_runs.session(i).updates_applied(), 0);
        }
    }

    /// Run fusion is refused exactly when an update could fire mid-run: an
    /// `EveryKSteps(1)` supervised run steps per-session (policy behaviour
    /// stays exact, just unfused), while a cadence the run cannot reach
    /// fuses fine.
    #[test]
    fn step_batched_runs_defers_to_solo_when_updates_can_fire() {
        let slots = [0usize, 1, 2];
        let runs: Vec<Vec<(Vec<f32>, StepTarget)>> = (0..3)
            .map(|i| {
                (0..2)
                    .map(|t| {
                        let x = vec![0.5 - 0.2 * i as f32, 0.1 * t as f32];
                        (x, StepTarget::Class((i + t) % 2))
                    })
                    .collect()
            })
            .collect();

        let mut eager = make_shared_pool(3, 13, UpdatePolicy::EveryKSteps(1), 1);
        let (outs, stats) = eager.step_batched_runs(&slots, &runs);
        assert_eq!(stats, BatchStats { fused_groups: 0, fused_lanes: 0, solo: 3 });
        for i in 0..3 {
            assert_eq!(outs[i].len(), 2);
            assert!(outs[i].iter().all(|o| o.updated), "every supervised step updates at k=1");
            assert_eq!(eager.session(i).updates_applied(), 2);
        }

        // pending (0) + supervised in run (2) < cadence (5) → provably no
        // mid-run update → the same run fuses
        let mut lazy = make_shared_pool(3, 13, UpdatePolicy::EveryKSteps(5), 1);
        let (outs2, stats2) = lazy.step_batched_runs(&slots, &runs);
        assert_eq!(stats2, BatchStats { fused_groups: 1, fused_lanes: 3, solo: 0 });
        assert!(outs2.iter().flatten().all(|o| !o.updated));
        for i in 0..3 {
            assert_eq!(lazy.session(i).updates_applied(), 0);
            assert_eq!(lazy.session(i).supervised_steps(), 2);
        }
    }
}
