//! [`SessionPool`]: N independent [`OnlineSession`]s driven concurrently —
//! the many-users serving scenario.
//!
//! Each session is a user's private learner (own weights, own optimizer
//! moments, own engine state); the pool fans work out over the in-tree
//! worker threads ([`crate::util::pool`]). Sessions are `Send` (the
//! [`crate::rtrl::GradientEngine`] contract requires it), so they migrate
//! freely between workers; results always return in session order.
//!
//! Idle users need not stay resident: [`SessionPool::evict`] spills a
//! session to disk through the snapshot codec facade
//! ([`crate::session::codec`], binary by default) and
//! [`SessionPool::admit`] restores it — bit-exactly, in either snapshot
//! format — when the user returns.

use super::codec::{self, SnapshotFormat};
use super::online::{OnlineSession, StepOutcome};
use crate::data::StepTarget;
use crate::util::pool::run_parallel;
use std::path::Path;

/// A fixed set of independent sessions plus a worker-thread budget.
pub struct SessionPool {
    sessions: Vec<OnlineSession>,
    workers: usize,
}

impl SessionPool {
    /// Wrap pre-built sessions. `workers = 0` uses the available hardware
    /// parallelism (the uniform `--threads` semantics of
    /// [`crate::util::pool::resolve_workers`]).
    pub fn new(sessions: Vec<OnlineSession>, workers: usize) -> Self {
        let workers = crate::util::pool::resolve_workers(workers);
        SessionPool { sessions, workers }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn session(&self, i: usize) -> &OnlineSession {
        &self.sessions[i]
    }

    pub fn session_mut(&mut self, i: usize) -> &mut OnlineSession {
        &mut self.sessions[i]
    }

    /// Tear down into the individual sessions (checkpointing each, say).
    pub fn into_sessions(self) -> Vec<OnlineSession> {
        self.sessions
    }

    /// Spill session `i` to `path` in the given snapshot format and drop it
    /// from the pool (later sessions shift down one index). The session is
    /// only removed after the snapshot is durably written, so a failed
    /// write never loses learner state.
    pub fn evict(&mut self, i: usize, path: &Path, format: SnapshotFormat) -> Result<(), String> {
        if i >= self.sessions.len() {
            return Err(format!("no session {i} in a pool of {}", self.sessions.len()));
        }
        let bytes = codec::encode(&self.sessions[i].checkpoint(), format);
        std::fs::write(path, &bytes)
            .map_err(|e| format!("cannot write snapshot {}: {e}", path.display()))?;
        self.sessions.remove(i);
        Ok(())
    }

    /// Restore a previously evicted session from `path` (either snapshot
    /// format, autodetected) and append it to the pool. Returns the new
    /// session's index. Resumption is bit-exact: the readmitted learner
    /// continues its stream as if it had never left memory.
    pub fn admit(&mut self, path: &Path) -> Result<usize, String> {
        let bytes = std::fs::read(path)
            .map_err(|e| format!("cannot read snapshot {}: {e}", path.display()))?;
        let ck = codec::decode(&bytes).map_err(|e| e.to_string())?;
        self.sessions.push(OnlineSession::resume(&ck)?);
        Ok(self.sessions.len() - 1)
    }

    /// Deliver one event per session (index-aligned) and step them all
    /// concurrently. Outcomes return in session order.
    pub fn step_all(&mut self, events: &[(Vec<f32>, StepTarget)]) -> Vec<StepOutcome> {
        assert_eq!(events.len(), self.sessions.len(), "one event per session");
        self.run_each(|i, s| {
            let (x, t) = &events[i];
            s.step(x, t.as_target())
        })
    }

    /// Run an arbitrary closure over every session concurrently (e.g. drain
    /// a per-user event queue); results return in session order. The
    /// sessions move to worker threads for the duration of the call.
    ///
    /// Failure containment: a panic in `f` for one session is caught at
    /// that session's boundary — every sibling still runs, **all** sessions
    /// (including the panicked one, whose learning state may be mid-step)
    /// return to the pool, and only then is the first panic re-raised.
    pub fn run_each<R, F>(&mut self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &mut OnlineSession) -> R + Sync,
    {
        use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
        let sessions = std::mem::take(&mut self.sessions);
        let results = run_parallel(sessions, self.workers, |i, mut s| {
            let r = catch_unwind(AssertUnwindSafe(|| f(i, &mut s)));
            (s, r)
        });
        let mut out = Vec::with_capacity(results.len());
        let mut first_panic = None;
        for (s, r) in results {
            self.sessions.push(s);
            match r {
                Ok(r) => out.push(r),
                Err(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
            }
        }
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, ExperimentConfig};
    use crate::rtrl::Target;
    use crate::session::{SessionBuilder, UpdatePolicy};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn make_pool(n: usize, workers: usize) -> SessionPool {
        let sessions = (0..n)
            .map(|i| {
                let mut cfg = ExperimentConfig::default();
                cfg.model.hidden = 6;
                cfg.seed = 100 + i as u64; // every user gets their own weights
                SessionBuilder::from_config(cfg)
                    .algorithm(AlgorithmKind::RtrlBoth)
                    .policy(UpdatePolicy::EveryKSteps(1))
                    .build()
            })
            .collect();
        SessionPool::new(sessions, workers)
    }

    /// ≥ 8 sessions stepping concurrently, many rounds, each learning its
    /// own stream — the acceptance bar for the many-users scenario.
    #[test]
    fn eight_concurrent_sessions_sustain_independent_streams() {
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let mut pool = make_pool(8, 8);
        for round in 0..30 {
            pool.run_each(|i, s| {
                let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
                PEAK.fetch_max(c, Ordering::SeqCst);
                // hold the slot briefly so overlap is observable even though
                // a single step only takes microseconds
                std::thread::sleep(std::time::Duration::from_millis(2));
                let x = [(round as f32 * 0.3 + i as f32).sin(), 0.5];
                let t = if round % 3 == 0 { Target::Class(i % 2) } else { Target::None };
                let o = s.step(&x, t);
                CUR.fetch_sub(1, Ordering::SeqCst);
                o
            });
        }
        assert!(PEAK.load(Ordering::SeqCst) >= 2, "sessions never overlapped");
        for i in 0..pool.len() {
            assert_eq!(pool.session(i).steps(), 30);
            assert_eq!(pool.session(i).supervised_steps(), 10);
            assert_eq!(pool.session(i).updates_applied(), 10);
        }
        // independent learners: different seeds → different weights
        let mut p0 = vec![0.0; pool.session(0).net().p()];
        let mut p1 = vec![0.0; pool.session(1).net().p()];
        pool.session(0).net().copy_params_into(&mut p0);
        pool.session(1).net().copy_params_into(&mut p1);
        assert_ne!(p0, p1);
    }

    /// One user's panic must not destroy the other users' learned state:
    /// after a contained panic, every session (including the offender) is
    /// still in the pool and the siblings' steps were applied.
    #[test]
    fn one_panicking_session_does_not_lose_the_others() {
        let mut pool = make_pool(6, 3);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_each(|i, s| {
                if i == 2 {
                    panic!("user 2 sent a poison event");
                }
                s.step(&[0.4, -0.4], Target::Class(i % 2))
            })
        }));
        assert!(caught.is_err(), "the panic must still surface");
        assert_eq!(pool.len(), 6, "sessions were lost from the pool");
        for i in 0..6 {
            let expect = if i == 2 { 0 } else { 1 };
            assert_eq!(pool.session(i).steps(), expect, "session {i} step count");
        }
        // the pool remains fully usable afterwards
        let outs = pool.run_each(|_, s| s.step(&[0.1, 0.2], Target::None));
        assert_eq!(outs.len(), 6);
    }

    /// `step_all` preserves session order and pairs events by index.
    #[test]
    fn step_all_is_index_aligned() {
        let mut pool = make_pool(4, 2);
        let events: Vec<(Vec<f32>, StepTarget)> = (0..4)
            .map(|i| (vec![i as f32, -1.0], StepTarget::Class(i % 2)))
            .collect();
        let outs = pool.step_all(&events);
        assert_eq!(outs.len(), 4);
        for o in &outs {
            assert_eq!(o.step, 1);
            assert!(o.loss.is_some());
        }
    }

    /// Evict a session to disk (binary snapshot), admit it back, and the
    /// readmitted learner produces bit-identical outcomes to a twin that
    /// never left memory.
    #[test]
    fn evict_admit_round_trip_is_bit_exact() {
        let dir = std::env::temp_dir()
            .join(format!("sparse-rtrl-pool-evict-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spill = dir.join("user1.snap");

        let mut pool = make_pool(3, 2);
        for round in 0..5 {
            pool.run_each(|i, s| {
                s.step(&[(i + round) as f32 * 0.2, -0.3], Target::Class((i + round) % 2))
            });
        }
        // twin of session 1 that stays resident
        let twin_ck = pool.session(1).checkpoint();
        let mut twin = OnlineSession::resume(&twin_ck).unwrap();

        pool.evict(1, &spill, SnapshotFormat::Binary).unwrap();
        assert_eq!(pool.len(), 2);
        assert!(pool.evict(7, &spill, SnapshotFormat::Binary).is_err());

        let idx = pool.admit(&spill).unwrap();
        assert_eq!((pool.len(), idx), (3, 2), "readmitted at the end");
        let back = pool.session_mut(idx);
        for round in 0..4 {
            let a = back.step(&[0.7, -0.1 * round as f32], Target::Class(round % 2));
            let b = twin.step(&[0.7, -0.1 * round as f32], Target::Class(round % 2));
            assert_eq!(a.loss.map(f32::to_bits), b.loss.map(f32::to_bits), "round {round}");
            assert_eq!(a.prediction, b.prediction);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Pool results are deterministic regardless of worker interleaving: a
    /// 1-worker pool and an 8-worker pool produce identical per-session
    /// outcomes.
    #[test]
    fn outcomes_independent_of_worker_count() {
        let run = |workers: usize| -> Vec<Vec<u32>> {
            let mut pool = make_pool(6, workers);
            let mut all = Vec::new();
            for round in 0..10 {
                let outs = pool.run_each(|i, s| {
                    let x = [(i as f32 - round as f32).cos(), 0.1];
                    s.step(&x, Target::Class((i + round) % 2))
                });
                all.push(outs.iter().map(|o| o.loss.unwrap().to_bits()).collect());
            }
            all
        };
        assert_eq!(run(1), run(8));
    }
}
