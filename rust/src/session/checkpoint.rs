//! Session checkpoints: migrate a live [`OnlineSession`] across process
//! restarts **bit-exactly**.
//!
//! A [`SessionCheckpoint`] captures everything a resumed session needs to
//! continue the stream as if never interrupted: the experiment config (the
//! recipe for stack topology and readout shape), the current weights, the
//! mid-accumulation gradient buffers, both optimizers' Adam moments, the
//! engine's [`EngineState`] snapshot (influence panels / UORO rank-1
//! vectors + RNG / SnAp slabs / BPTT tape), the per-layer sparsity masks
//! (which may have drifted from the config via rewiring), the stream
//! counters, and the op counters (so cost accounting keeps accumulating
//! across the migration instead of restarting at zero).
//!
//! Serialization reuses the in-tree JSON from [`crate::bench::json`]. Two
//! encoding rules keep restores bit-exact across platforms:
//!
//! * every `f32` travels as its IEEE-754 **bit pattern** (a `u32` JSON
//!   number — exactly representable as an `f64`), never as a decimal float;
//! * every `u64` travels as a **decimal string** (64-bit RNG state words do
//!   not fit exactly in a JSON double).
//!
//! `tests/session_checkpoint.rs` pins the contract for all engines, and the
//! `stream` CLI round-trips checkpoints across real process boundaries.

use super::online::{OnlineSession, SessionBuilder, UpdatePolicy};
use crate::bench::json::{escape, parse, Json};
use crate::config::ExperimentConfig;
use crate::optim::AdamState;
use crate::rtrl::EngineState;
use crate::sparse::MaskPattern;
use crate::util::Pcg64;

/// Schema identifier of the checkpoint document.
pub const SCHEMA: &str = "sparse-rtrl/session/v1";
/// Monotone document revision; bump on breaking field changes.
pub const SCHEMA_VERSION: u64 = 1;

/// A complete, serializable snapshot of one [`OnlineSession`].
#[derive(Debug, Clone)]
pub struct SessionCheckpoint {
    /// Full experiment config (TOML text — the topology recipe).
    pub config_toml: String,
    pub policy: UpdatePolicy,
    pub predict_always: bool,
    pub steps: u64,
    pub supervised_steps: u64,
    pub updates_applied: u64,
    pub pending_supervised: u64,
    /// Concatenated recurrent parameters (`R^P`).
    pub net_params: Vec<f32>,
    pub readout_params: Vec<f32>,
    /// Mid-accumulation readout gradients.
    pub readout_grads: Vec<f32>,
    /// Harvested-but-unapplied recurrent gradient.
    pub grad_accum: Vec<f32>,
    pub opt_cell: AdamState,
    pub opt_readout: AdamState,
    /// Per-layer kept flat indices (`r·n + c`) of the recurrent mask, or
    /// `None` for dense layers. Saved explicitly because rewiring can move
    /// masks away from their config-seeded pattern.
    pub masks: Vec<Option<Vec<u64>>>,
    /// The session's op counters ([`crate::metrics::OpCounter`] word form),
    /// so cost accounting also survives migration.
    pub ops: Vec<u64>,
    /// The gradient engine's own versioned snapshot.
    pub engine: EngineState,
}

impl OnlineSession {
    /// Snapshot the session between steps. The checkpoint is self-contained:
    /// [`OnlineSession::resume`] in a fresh process continues the stream
    /// with bit-identical predictions, gradients and updates.
    pub fn checkpoint(&self) -> SessionCheckpoint {
        let mut net_params = vec![0.0; self.net.p()];
        self.net.copy_params_into(&mut net_params);
        let mut readout_params = vec![0.0; self.readout.param_len()];
        self.readout.copy_params_into(&mut readout_params);
        let mut readout_grads = vec![0.0; self.readout.param_len()];
        self.readout.copy_grads_into(&mut readout_grads);
        let masks = (0..self.net.layers())
            .map(|l| {
                self.net.layer(l).mask().map(|m| {
                    let n = self.net.layer(l).n();
                    let mut kept = Vec::with_capacity(m.kept());
                    for r in 0..n {
                        for c in 0..n {
                            if m.is_kept(r, c) {
                                kept.push((r * n + c) as u64);
                            }
                        }
                    }
                    kept
                })
            })
            .collect();
        SessionCheckpoint {
            config_toml: self.cfg.to_toml(),
            policy: self.policy,
            predict_always: self.predict_always,
            steps: self.steps,
            supervised_steps: self.supervised_steps,
            updates_applied: self.updates_applied,
            pending_supervised: self.pending_supervised,
            net_params,
            readout_params,
            readout_grads,
            grad_accum: self.grad_accum.clone(),
            opt_cell: self.opt_cell.save_state(),
            opt_readout: self.opt_readout.save_state(),
            masks,
            ops: self.ops.to_words_vec(),
            engine: self.engine.save_state(),
        }
    }

    /// Rebuild a session from a checkpoint. The stack topology is rebuilt
    /// from the embedded config, masks are restored verbatim, and every
    /// float buffer is loaded bit-for-bit.
    pub fn resume(ck: &SessionCheckpoint) -> Result<OnlineSession, String> {
        let cfg = ExperimentConfig::from_toml(&ck.config_toml)
            .map_err(|e| format!("checkpoint config: {e}"))?;
        let mut s = SessionBuilder::from_config(cfg)
            .policy(ck.policy)
            .predict_always(ck.predict_always)
            .build();
        if ck.masks.len() != s.net.layers() {
            return Err(format!(
                "checkpoint has {} mask entries for a {}-layer stack",
                ck.masks.len(),
                s.net.layers()
            ));
        }
        let mut mask_rng = Pcg64::new(0); // grown-entry init is overwritten by load_params
        for l in 0..s.net.layers() {
            match &ck.masks[l] {
                Some(kept) => {
                    let n = s.net.layer(l).n();
                    let mut keep = vec![false; n * n];
                    for &flat in kept {
                        let flat = flat as usize;
                        if flat >= n * n {
                            return Err(format!("layer {l}: mask index {flat} out of range"));
                        }
                        keep[flat] = true;
                    }
                    s.net.layer_mut(l).set_mask(
                        MaskPattern::from_bools(n, n, keep),
                        0.0,
                        &mut mask_rng,
                    );
                }
                None => {
                    if s.net.layer(l).mask().is_some() {
                        return Err(format!(
                            "layer {l}: config builds a masked layer but the checkpoint has no mask"
                        ));
                    }
                }
            }
        }
        // Engine must be derived from the *restored* masks before its state
        // loads (column maps / SnAp patterns follow the mask).
        s.rebuild_engine();
        if ck.net_params.len() != s.net.p() {
            return Err(format!(
                "checkpoint carries {} recurrent params, stack has {}",
                ck.net_params.len(),
                s.net.p()
            ));
        }
        if ck.readout_params.len() != s.readout.param_len()
            || ck.readout_grads.len() != s.readout.param_len()
        {
            return Err("checkpoint readout buffers do not match the readout shape".into());
        }
        if ck.grad_accum.len() != s.net.p() {
            return Err("checkpoint gradient accumulator does not match P".into());
        }
        s.net.load_params(&ck.net_params);
        s.readout.load_params(&ck.readout_params);
        s.readout.load_grads(&ck.readout_grads);
        s.grad_accum.copy_from_slice(&ck.grad_accum);
        s.opt_cell.load_state(&ck.opt_cell)?;
        s.opt_readout.load_state(&ck.opt_readout)?;
        s.engine.load_state(&s.net, &ck.engine).map_err(|e| e.to_string())?;
        s.ops = crate::metrics::OpCounter::from_words_vec(&ck.ops)?;
        s.steps = ck.steps;
        s.supervised_steps = ck.supervised_steps;
        s.updates_applied = ck.updates_applied;
        s.pending_supervised = ck.pending_supervised;
        Ok(s)
    }
}

// ---------------------------------------------------------------------
// JSON encoding
// ---------------------------------------------------------------------

/// f32 slice → JSON array of IEEE-754 bit patterns.
fn bits_array(xs: &[f32]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_bits().to_string()).collect();
    format!("[{}]", items.join(","))
}

/// u64 slice → JSON array of decimal strings (exact at full 64-bit width).
fn u64_array(xs: &[u64]) -> String {
    let items: Vec<String> = xs.iter().map(|x| format!("\"{x}\"")).collect();
    format!("[{}]", items.join(","))
}

/// Canonical (name, k) form of an [`UpdatePolicy`] — shared by the JSON
/// document and the binary codec so the two formats can never disagree on
/// the policy vocabulary.
pub(crate) fn policy_name(p: UpdatePolicy) -> (&'static str, u64) {
    match p {
        UpdatePolicy::EveryKSteps(k) => ("every_k", k),
        UpdatePolicy::EndOfSequence => ("sequence", 0),
        UpdatePolicy::Manual => ("manual", 0),
    }
}

/// Inverse of [`policy_name`]; rejects unknown names and `every_k` with
/// `k = 0`.
pub(crate) fn policy_from(name: &str, k: u64) -> Result<UpdatePolicy, String> {
    match name {
        "every_k" if k == 0 => Err("update_every must be ≥ 1 for the every_k policy".into()),
        "every_k" => Ok(UpdatePolicy::EveryKSteps(k)),
        "sequence" => Ok(UpdatePolicy::EndOfSequence),
        "manual" => Ok(UpdatePolicy::Manual),
        other => Err(format!("unknown update policy {other:?}")),
    }
}

impl SessionCheckpoint {
    /// Serialize to the versioned JSON document.
    pub fn to_json(&self) -> String {
        let (policy, k) = policy_name(self.policy);
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": \"{}\",\n", escape(SCHEMA)));
        s.push_str(&format!("  \"schema_version\": \"{SCHEMA_VERSION}\",\n"));
        s.push_str(&format!("  \"config_toml\": \"{}\",\n", escape(&self.config_toml)));
        s.push_str(&format!("  \"policy\": \"{policy}\",\n"));
        s.push_str(&format!("  \"update_every\": \"{k}\",\n"));
        s.push_str(&format!("  \"predict_always\": {},\n", self.predict_always));
        s.push_str(&format!("  \"steps\": \"{}\",\n", self.steps));
        s.push_str(&format!("  \"supervised_steps\": \"{}\",\n", self.supervised_steps));
        s.push_str(&format!("  \"updates_applied\": \"{}\",\n", self.updates_applied));
        s.push_str(&format!("  \"pending_supervised\": \"{}\",\n", self.pending_supervised));
        s.push_str(&format!("  \"net_params\": {},\n", bits_array(&self.net_params)));
        s.push_str(&format!("  \"readout_params\": {},\n", bits_array(&self.readout_params)));
        s.push_str(&format!("  \"readout_grads\": {},\n", bits_array(&self.readout_grads)));
        s.push_str(&format!("  \"grad_accum\": {},\n", bits_array(&self.grad_accum)));
        s.push_str(&format!("  \"opt_cell_m\": {},\n", bits_array(&self.opt_cell.m)));
        s.push_str(&format!("  \"opt_cell_v\": {},\n", bits_array(&self.opt_cell.v)));
        s.push_str(&format!("  \"opt_cell_t\": \"{}\",\n", self.opt_cell.t));
        s.push_str(&format!("  \"opt_readout_m\": {},\n", bits_array(&self.opt_readout.m)));
        s.push_str(&format!("  \"opt_readout_v\": {},\n", bits_array(&self.opt_readout.v)));
        s.push_str(&format!("  \"opt_readout_t\": \"{}\",\n", self.opt_readout.t));
        let masks: Vec<String> = self
            .masks
            .iter()
            .map(|m| match m {
                None => "null".to_string(),
                Some(kept) => u64_array(kept),
            })
            .collect();
        s.push_str(&format!("  \"masks\": [{}],\n", masks.join(", ")));
        s.push_str(&format!("  \"ops\": {},\n", u64_array(&self.ops)));
        s.push_str("  \"engine\": {\n");
        s.push_str(&format!("    \"name\": \"{}\",\n", escape(&self.engine.engine)));
        s.push_str(&format!("    \"version\": \"{}\",\n", self.engine.version));
        let ints: Vec<String> = self
            .engine
            .int_entries()
            .map(|(key, v)| format!("\"{}\": {}", escape(key), u64_array(v)))
            .collect();
        s.push_str(&format!("    \"ints\": {{{}}},\n", ints.join(", ")));
        let floats: Vec<String> = self
            .engine
            .float_entries()
            .map(|(key, v)| format!("\"{}\": {}", escape(key), bits_array(v)))
            .collect();
        s.push_str(&format!("    \"floats\": {{{}}}\n", floats.join(", ")));
        s.push_str("  }\n}\n");
        s
    }

    /// Parse a [`SessionCheckpoint::to_json`] document.
    pub fn from_json(text: &str) -> Result<SessionCheckpoint, String> {
        let doc = parse(text)?;
        let schema = str_of(&doc, "schema")?;
        if schema != SCHEMA {
            return Err(format!("not a session checkpoint (schema {schema:?})"));
        }
        let version = u64_of(&doc, "schema_version")?;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "checkpoint schema_version {version} unsupported (this build reads {SCHEMA_VERSION})"
            ));
        }
        let policy = policy_from(str_of(&doc, "policy")?, u64_of(&doc, "update_every")?)?;
        let predict_always = match doc.get("predict_always") {
            Some(Json::Bool(b)) => *b,
            _ => return Err("predict_always must be a bool".into()),
        };
        let engine_doc =
            doc.get("engine").ok_or_else(|| "missing engine section".to_string())?;
        let engine_version = u64_of(engine_doc, "version")?;
        if engine_version > u32::MAX as u64 {
            return Err(format!("engine state version {engine_version} out of range"));
        }
        let mut engine =
            EngineState::new(str_of(engine_doc, "name")?, engine_version as u32);
        for (key, val) in obj_of(engine_doc, "ints")? {
            engine.put_ints(key, u64s_from(val, key)?);
        }
        for (key, val) in obj_of(engine_doc, "floats")? {
            engine.put_floats(key, floats_from(val, key)?);
        }
        let masks_arr = doc
            .get("masks")
            .and_then(Json::as_arr)
            .ok_or_else(|| "masks must be an array".to_string())?;
        let masks = masks_arr
            .iter()
            .map(|m| match m {
                Json::Null => Ok(None),
                other => u64s_from(other, "masks").map(Some),
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(SessionCheckpoint {
            config_toml: str_of(&doc, "config_toml")?.to_string(),
            policy,
            predict_always,
            steps: u64_of(&doc, "steps")?,
            supervised_steps: u64_of(&doc, "supervised_steps")?,
            updates_applied: u64_of(&doc, "updates_applied")?,
            pending_supervised: u64_of(&doc, "pending_supervised")?,
            net_params: floats_of(&doc, "net_params")?,
            readout_params: floats_of(&doc, "readout_params")?,
            readout_grads: floats_of(&doc, "readout_grads")?,
            grad_accum: floats_of(&doc, "grad_accum")?,
            opt_cell: AdamState {
                m: floats_of(&doc, "opt_cell_m")?,
                v: floats_of(&doc, "opt_cell_v")?,
                t: u64_of(&doc, "opt_cell_t")?,
            },
            opt_readout: AdamState {
                m: floats_of(&doc, "opt_readout_m")?,
                v: floats_of(&doc, "opt_readout_v")?,
                t: u64_of(&doc, "opt_readout_t")?,
            },
            masks,
            ops: u64s_from(
                doc.get("ops").ok_or_else(|| "missing ops array".to_string())?,
                "ops",
            )?,
            engine,
        })
    }
}

// ---------------------------------------------------------------------
// Parsing helpers over the bench-json value tree
// ---------------------------------------------------------------------

fn str_of<'a>(doc: &'a Json, key: &str) -> Result<&'a str, String> {
    doc.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing string field {key:?}"))
}

/// u64 stored as a decimal string.
fn u64_of(doc: &Json, key: &str) -> Result<u64, String> {
    str_of(doc, key)?
        .parse::<u64>()
        .map_err(|_| format!("field {key:?} is not a u64 string"))
}

fn obj_of<'a>(doc: &'a Json, key: &str) -> Result<Vec<(&'a str, &'a Json)>, String> {
    match doc.get(key) {
        Some(Json::Obj(m)) => Ok(m.iter().map(|(k, v)| (k.as_str(), v)).collect()),
        _ => Err(format!("missing object field {key:?}")),
    }
}

fn floats_of(doc: &Json, key: &str) -> Result<Vec<f32>, String> {
    let arr = doc
        .get(key)
        .ok_or_else(|| format!("missing float array {key:?}"))?;
    floats_from(arr, key)
}

/// JSON array of u32 bit patterns → f32 values.
fn floats_from(arr: &Json, key: &str) -> Result<Vec<f32>, String> {
    arr.as_arr()
        .ok_or_else(|| format!("{key:?} must be an array"))?
        .iter()
        .map(|v| {
            let bits = v
                .as_u64()
                .filter(|&b| b <= u32::MAX as u64)
                .ok_or_else(|| format!("{key:?} holds a non-u32 bit pattern"))?;
            Ok(f32::from_bits(bits as u32))
        })
        .collect()
}

/// JSON array of decimal strings → u64 values.
fn u64s_from(arr: &Json, key: &str) -> Result<Vec<u64>, String> {
    arr.as_arr()
        .ok_or_else(|| format!("{key:?} must be an array"))?
        .iter()
        .map(|v| {
            v.as_str()
                .and_then(|s| s.parse::<u64>().ok())
                .ok_or_else(|| format!("{key:?} holds a non-u64 entry"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;
    use crate::rtrl::Target;

    #[test]
    fn json_roundtrip_preserves_every_bit() {
        let mut cfg = ExperimentConfig::default();
        cfg.model.hidden = 6;
        cfg.model.param_sparsity = 0.5;
        let mut s = SessionBuilder::from_config(cfg)
            .algorithm(AlgorithmKind::Uoro)
            .predict_always(true)
            .build();
        for i in 0..7 {
            let x = [0.3 * i as f32, -0.1];
            let t = if i % 2 == 1 { Target::Class(i % 2) } else { Target::None };
            s.step(&x, t);
        }
        let ck = s.checkpoint();
        let back = SessionCheckpoint::from_json(&ck.to_json()).expect("parse");
        assert_eq!(back.config_toml, ck.config_toml);
        assert_eq!(back.policy, ck.policy);
        assert_eq!(back.predict_always, ck.predict_always);
        assert_eq!(back.steps, ck.steps);
        // exact f32 bit equality, including any negative zeros / denormals
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.net_params), bits(&ck.net_params));
        assert_eq!(bits(&back.grad_accum), bits(&ck.grad_accum));
        assert_eq!(bits(&back.opt_cell.m), bits(&ck.opt_cell.m));
        assert_eq!(back.opt_cell.t, ck.opt_cell.t);
        assert_eq!(back.masks, ck.masks);
        assert_eq!(back.ops, ck.ops);
        assert_eq!(back.engine, ck.engine);
    }

    #[test]
    fn special_float_values_survive() {
        let mut s = SessionBuilder::new().build();
        s.grad_accum[0] = -0.0;
        s.grad_accum[1] = f32::from_bits(1); // smallest denormal
        s.grad_accum[2] = f32::NEG_INFINITY;
        let ck = s.checkpoint();
        let back = SessionCheckpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back.grad_accum[0].to_bits(), (-0.0f32).to_bits());
        assert_eq!(back.grad_accum[1].to_bits(), 1);
        assert_eq!(back.grad_accum[2], f32::NEG_INFINITY);
    }

    #[test]
    fn wrong_schema_rejected() {
        assert!(SessionCheckpoint::from_json("{\"schema\": \"other\"}").is_err());
        assert!(SessionCheckpoint::from_json("not json").is_err());
    }

    /// Tampered policy/version fields fail loudly instead of being clamped.
    #[test]
    fn tampered_fields_rejected() {
        let good = SessionBuilder::new().build().checkpoint().to_json();
        let zero_k = good.replace("\"update_every\": \"1\"", "\"update_every\": \"0\"");
        assert!(SessionCheckpoint::from_json(&zero_k).is_err(), "k=0 must be rejected");
        let big_version =
            good.replace("\"version\": \"1\"", &format!("\"version\": \"{}\"", u64::MAX));
        assert!(
            SessionCheckpoint::from_json(&big_version).is_err(),
            "out-of-range engine version must be rejected"
        );
    }

    #[test]
    fn resume_rejects_mismatched_engine_kind() {
        let mut ck = SessionBuilder::new().build().checkpoint();
        ck.engine = EngineState::new("bptt", 1); // session config says rtrl-both
        assert!(OnlineSession::resume(&ck).is_err());
    }
}
