//! [`OnlineSession`]: a long-lived, step-driven learner — the crate's
//! primary API surface.
//!
//! A session owns the full learning state (stack, readout, gradient engine,
//! optimizer moments, op counters) and consumes an **event stream**: every
//! [`OnlineSession::step`] takes one `(input, target)` pair and returns a
//! [`StepOutcome`] with the prediction, the instantaneous loss and the
//! step's sparsity observations. There are no mandatory sequence
//! boundaries — [`UpdatePolicy`] decides when the accumulated RTRL gradient
//! is turned into a parameter update, and [`OnlineSession::begin_sequence`]
//! / [`OnlineSession::end_sequence`] exist only for workloads that *have*
//! boundaries (the batch trainer is one such client).

use crate::config::ExperimentConfig;
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, LossKind, Readout};
use crate::optim::{Adam, Optimizer};
use crate::rtrl::{GradientEngine, StepResult, Target};
use crate::telemetry::{SessionTelemetry, TelemetryConfig};
use crate::train::build;
use crate::util::Pcg64;

/// When a session turns accumulated gradients into a parameter update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdatePolicy {
    /// Apply after every `k ≥ 1` *supervised* steps — the paper's online
    /// regime at `k = 1`. (With BPTT this truncates the tape at each
    /// update, i.e. truncated BPTT; the RTRL engines carry their influence
    /// state across updates with no approximation.)
    EveryKSteps(u64),
    /// Apply at [`OnlineSession::end_sequence`] boundaries.
    EndOfSequence,
    /// Never apply automatically; the caller harvests via `end_sequence`
    /// and applies via [`OnlineSession::apply_update`] (how the batch
    /// trainer averages gradients over a minibatch).
    Manual,
}

/// Everything one [`OnlineSession::step`] reports.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepOutcome {
    /// 1-based stream position of this step.
    pub step: u64,
    /// Instantaneous loss, when the step carried a target.
    pub loss: Option<f32>,
    /// Whether a class prediction matched the target.
    pub correct: Option<bool>,
    /// Predicted class — present on supervised classification steps, and on
    /// unsupervised steps too when the session runs in serving mode (see
    /// [`SessionBuilder::predict_always`]). `None` on regression
    /// ([`crate::rtrl::Target::Vector`]) steps.
    pub prediction: Option<usize>,
    /// Units with nonzero activation (α̃N).
    pub active_units: usize,
    /// Units with nonzero pseudo-derivative (β̃N).
    pub deriv_units: usize,
    /// Influence-matrix zero fraction, when measurement is on.
    pub influence_sparsity: Option<f32>,
    /// Whether this step triggered a parameter update.
    pub updated: bool,
}

/// Builder for [`OnlineSession`] — programmatic or straight from an
/// [`ExperimentConfig`]. Weight init replays the trainer's RNG stream
/// order, so a session and a [`crate::train::Trainer`] built from the same
/// config see identical parameters.
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    cfg: ExperimentConfig,
    policy: UpdatePolicy,
    predict_always: bool,
    threads: usize,
    telemetry: Option<TelemetryConfig>,
}

impl SessionBuilder {
    /// Start from a config (the TOML-level description of model + task +
    /// training hyperparameters).
    pub fn from_config(cfg: ExperimentConfig) -> Self {
        SessionBuilder {
            cfg,
            policy: UpdatePolicy::EveryKSteps(1),
            predict_always: false,
            threads: 1,
            telemetry: None,
        }
    }

    /// Default configuration (paper spiral setup), for programmatic use.
    pub fn new() -> Self {
        Self::from_config(ExperimentConfig::default())
    }

    /// Set the update policy (default: update every supervised step).
    /// Panics on `EveryKSteps(0)` — a zero cadence is a caller bug, not a
    /// value to silently reinterpret.
    pub fn policy(mut self, policy: UpdatePolicy) -> Self {
        if let UpdatePolicy::EveryKSteps(0) = policy {
            panic!("UpdatePolicy::EveryKSteps requires k ≥ 1");
        }
        self.policy = policy;
        self
    }

    /// Gradient engine selection.
    pub fn algorithm(mut self, kind: crate::config::AlgorithmKind) -> Self {
        self.cfg.train.algorithm = kind;
        self
    }

    /// Weight-init / mask seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Hidden units per layer.
    pub fn hidden(mut self, n: usize) -> Self {
        self.cfg.model.hidden = n;
        self
    }

    /// Stack depth (≥ 1).
    pub fn layers(mut self, l: usize) -> Self {
        assert!(l >= 1, "layers must be ≥ 1");
        self.cfg.model.layers = l;
        self
    }

    /// Recurrent parameter sparsity ω ∈ [0, 1).
    pub fn param_sparsity(mut self, w: f32) -> Self {
        assert!((0.0..1.0).contains(&w), "param_sparsity must be in [0,1)");
        self.cfg.model.param_sparsity = w;
        self
    }

    /// Learning rate for both optimizers.
    pub fn lr(mut self, lr: f32) -> Self {
        self.cfg.train.lr = lr;
        self
    }

    /// Run a readout-only prediction on *unsupervised* steps too, so every
    /// [`StepOutcome`] carries a class (serving mode; costs one readout
    /// forward per unsupervised step, charged to the session's op counter).
    pub fn predict_always(mut self, on: bool) -> Self {
        self.predict_always = on;
        self
    }

    /// Worker threads for the engine's intra-step kernels (`0` = available
    /// hardware parallelism, `1` = serial — the default). A runtime knob,
    /// not session state: it never travels in checkpoints, and results are
    /// bit-identical at any value ([`crate::rtrl::GradientEngine::set_threads`]).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Enable per-session telemetry sampling from the first step (see
    /// [`OnlineSession::enable_telemetry`]). Default: disabled — and
    /// disabled really is off: no clock reads, no sampling, one `Option`
    /// discriminant test per step.
    pub fn telemetry(mut self, cfg: TelemetryConfig) -> Self {
        self.telemetry = Some(cfg);
        self
    }

    /// Build the session. RNG streams split in the same order as
    /// [`crate::train::Trainer::new`] (cell, readout, data, batch), so the
    /// two surfaces are weight-for-weight interchangeable.
    pub fn build(self) -> OnlineSession {
        let cfg = self.cfg;
        let mut root = Pcg64::new(cfg.seed);
        let mut cell_rng = root.split();
        let mut readout_rng = root.split();
        let _data_rng = root.split();
        let _batch_rng = root.split();
        let n_out = build::task_n_out(&cfg);
        let net = build::build_stack(&cfg, &mut cell_rng);
        let readout = Readout::new(n_out, net.top_n(), &mut readout_rng);
        let mut engine = build::build_engine(cfg.train.algorithm, &net, n_out);
        engine.set_threads(self.threads);
        engine.begin_sequence();
        let p = net.p();
        let rp = readout.param_len();
        let lr = cfg.train.lr;
        let mut session = OnlineSession {
            cfg,
            net,
            readout,
            loss: Loss::new(LossKind::CrossEntropy, n_out),
            engine,
            opt_cell: Adam::new(p, lr),
            opt_readout: Adam::new(rp, lr),
            policy: self.policy,
            predict_always: self.predict_always,
            threads: self.threads,
            grad_accum: vec![0.0; p],
            cell_params: vec![0.0; p],
            readout_params: vec![0.0; rp],
            readout_grads: vec![0.0; rp],
            logits: vec![0.0; n_out],
            ops: OpCounter::new(),
            steps: 0,
            supervised_steps: 0,
            updates_applied: 0,
            pending_supervised: 0,
            telemetry: None,
        };
        if let Some(tc) = self.telemetry {
            session.enable_telemetry(tc);
        }
        session
    }
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A long-lived online learner over an event stream. See the module docs;
/// built by [`SessionBuilder`], checkpointed by
/// [`OnlineSession::checkpoint`] (see [`crate::session::checkpoint`]).
pub struct OnlineSession {
    pub(crate) cfg: ExperimentConfig,
    pub(crate) net: LayerStack,
    pub(crate) readout: Readout,
    pub(crate) loss: Loss,
    pub(crate) engine: Box<dyn GradientEngine>,
    pub(crate) opt_cell: Adam,
    pub(crate) opt_readout: Adam,
    pub(crate) policy: UpdatePolicy,
    pub(crate) predict_always: bool,
    /// Intra-step kernel threads (runtime knob; reapplied on engine
    /// rebuild, never checkpointed).
    pub(crate) threads: usize,
    /// Harvested-but-unapplied gradient (`R^P`), summed across harvests.
    pub(crate) grad_accum: Vec<f32>,
    cell_params: Vec<f32>,
    readout_params: Vec<f32>,
    readout_grads: Vec<f32>,
    logits: Vec<f32>,
    /// Every MAC the session performs, phase- and layer-attributed.
    pub ops: OpCounter,
    pub(crate) steps: u64,
    pub(crate) supervised_steps: u64,
    pub(crate) updates_applied: u64,
    /// Supervised steps whose gradient has not been applied yet.
    pub(crate) pending_supervised: u64,
    /// Metric sampler; `None` = telemetry off (the default). A runtime
    /// observability knob like `threads`: never part of a checkpoint, so a
    /// resumed session starts with telemetry off regardless of what the
    /// evicted session had enabled.
    pub(crate) telemetry: Option<SessionTelemetry>,
}

impl OnlineSession {
    /// The configuration the session was built from.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The recurrent stack.
    pub fn net(&self) -> &LayerStack {
        &self.net
    }

    /// Output width: the class count for classification targets, and the
    /// required length of [`crate::rtrl::Target::Vector`] regression
    /// targets.
    pub fn n_out(&self) -> usize {
        self.readout.n_out()
    }

    /// Mutable stack access (mask rewiring). Callers that change masks must
    /// [`OnlineSession::rebuild_engine`] afterwards.
    pub fn net_mut(&mut self) -> &mut LayerStack {
        &mut self.net
    }

    /// The linear readout.
    pub fn readout(&self) -> &Readout {
        &self.readout
    }

    /// The gradient engine (state-memory queries, grads inspection).
    pub fn engine(&self) -> &dyn GradientEngine {
        &*self.engine
    }

    /// The recurrent-parameter optimizer (moment surgery after rewiring).
    pub fn optimizer_cell_mut(&mut self) -> &mut Adam {
        &mut self.opt_cell
    }

    /// The active update policy.
    pub fn policy(&self) -> UpdatePolicy {
        self.policy
    }

    /// Steps consumed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Supervised steps consumed so far.
    pub fn supervised_steps(&self) -> u64 {
        self.supervised_steps
    }

    /// Parameter updates applied so far.
    pub fn updates_applied(&self) -> u64 {
        self.updates_applied
    }

    /// Rebuild the gradient engine from the current stack (after mask
    /// rewiring: column maps and SnAp patterns must track the new
    /// structure). Influence state restarts at zero — exact for just-grown
    /// parameters, which have had no past influence.
    pub fn rebuild_engine(&mut self) {
        self.engine =
            build::build_engine(self.cfg.train.algorithm, &self.net, self.readout.n_out());
        self.engine.set_threads(self.threads);
        if self.telemetry.as_ref().is_some_and(|t| t.config().measure_influence) {
            self.engine.set_measure_influence(true);
        }
        self.engine.begin_sequence();
    }

    /// Toggle influence-sparsity measurement on the engine.
    pub fn set_measure_influence(&mut self, on: bool) {
        self.engine.set_measure_influence(on);
    }

    /// Set the intra-step kernel thread count (`0` = available hardware
    /// parallelism). Safe at any point — including on a resumed session:
    /// results are bit-identical at any value, so this is a pure
    /// wall-clock knob and is never part of a checkpoint.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
        self.engine.set_threads(threads);
    }

    /// Turn on per-session metric sampling (α/β/β̃, influence occupancy,
    /// loss EWMA, per-phase MAC rates, step latency) with the given knobs.
    /// Works at any point in a session's life — including on a resumed
    /// session, since telemetry never travels in checkpoints. Op-rate
    /// baselines anchor at the *current* counter values, so mid-stream
    /// enables report rates for the observed suffix only.
    ///
    /// With [`TelemetryConfig::measure_influence`] the engine also measures
    /// influence-panel occupancy each step: pure inspection (zero ops, no
    /// gradient effect), but it costs wall time proportional to the panel.
    pub fn enable_telemetry(&mut self, cfg: TelemetryConfig) {
        if cfg.measure_influence {
            self.engine.set_measure_influence(true);
        }
        self.telemetry = Some(SessionTelemetry::new(cfg, self.net.total_units(), &self.ops));
    }

    /// Drop the sampler (and any influence measurement it switched on),
    /// returning the session to the zero-overhead path.
    pub fn disable_telemetry(&mut self) {
        if self.telemetry.as_ref().is_some_and(|t| t.config().measure_influence) {
            self.engine.set_measure_influence(false);
        }
        self.telemetry = None;
    }

    /// The metric sampler, when telemetry is enabled.
    pub fn telemetry(&self) -> Option<&SessionTelemetry> {
        self.telemetry.as_ref()
    }

    /// Mutable sampler access (trace emitters drain fresh points here).
    pub fn telemetry_mut(&mut self) -> Option<&mut SessionTelemetry> {
        self.telemetry.as_mut()
    }

    /// Reset the engine's temporal state for a new sequence. Optional: a
    /// boundary-free stream never calls this.
    pub fn begin_sequence(&mut self) {
        self.engine.begin_sequence();
    }

    /// Consume one stream event. Runs the engine step, optionally a
    /// readout-only prediction (serving mode), then lets the update policy
    /// decide whether to apply the accumulated gradient.
    pub fn step(&mut self, x: &[f32], target: Target<'_>) -> StepOutcome {
        assert_eq!(x.len(), self.net.n_in(), "input width must match the stack");
        // The only per-step telemetry cost when disabled is this `is_some`
        // test — the clock is not even read (tests/telemetry.rs pins that
        // outcomes are bit-identical either way).
        // analyze: allow(ambient-time) -- telemetry latency clock, gated off the hot path
        let t0 = if self.telemetry.is_some() { Some(std::time::Instant::now()) } else { None };
        let r = self.engine.step(
            &self.net,
            &mut self.readout,
            &mut self.loss,
            x,
            target,
            &mut self.ops,
        );
        self.absorb_step_result(r, t0)
    }

    /// Per-session bookkeeping after an engine step that ran *outside*
    /// `self.engine` — the tail of [`Self::step`], shared with
    /// [`crate::session::SessionPool::step_batched`]'s shared-weight
    /// batched path. The engine's post-step state must already be in place
    /// (serving-mode prediction reads `engine.activations()`, and a policy
    /// update harvests the engine's gradient).
    pub(crate) fn absorb_step_result(
        &mut self,
        r: StepResult,
        // analyze: allow(ambient-time) -- carries the caller's telemetry clock, never reads one
        t0: Option<std::time::Instant>,
    ) -> StepOutcome {
        self.absorb_step_result_from(r, t0, None)
    }

    /// [`Self::absorb_step_result`] with an optional activation override:
    /// `acts` supplies the post-step activations when the step ran in a
    /// fused group engine whose state has *not* been written back yet
    /// ([`crate::session::SessionPool::step_batched_runs`] defers the
    /// write-back to the end of a run). Callers deferring the write-back
    /// must guarantee no update policy can fire during the run — an update
    /// harvests `self.engine`, which would still hold pre-run state.
    pub(crate) fn absorb_step_result_from(
        &mut self,
        r: StepResult,
        // analyze: allow(ambient-time) -- carries the caller's telemetry clock, never reads one
        t0: Option<std::time::Instant>,
        acts: Option<&[f32]>,
    ) -> StepOutcome {
        self.steps += 1;
        let mut prediction = r.prediction;
        if r.loss.is_none() && self.predict_always {
            // Unsupervised step in serving mode: readout-only forward on the
            // freshly-produced top activations (the recurrent forward already
            // ran inside the engine). Supervised steps already ran the
            // readout; regression (Vector) steps deliberately keep
            // `prediction = None` rather than argmax-ing an MSE output.
            let top_off = self.net.layout().state_offset(self.net.layers() - 1);
            let a = match acts {
                Some(a) => a,
                None => self.engine.activations(),
            };
            self.readout.forward(&a[top_off..], &mut self.logits, &mut self.ops);
            prediction = Some(Loss::predict(&self.logits));
        }
        if r.loss.is_some() {
            self.supervised_steps += 1;
            self.pending_supervised += 1;
        }
        let updated = match self.policy {
            UpdatePolicy::EveryKSteps(k) if self.pending_supervised >= k => {
                self.harvest();
                self.apply_update(1.0);
                true
            }
            _ => false,
        };
        let outcome = StepOutcome {
            step: self.steps,
            loss: r.loss,
            correct: r.correct,
            prediction,
            active_units: r.active_units,
            deriv_units: r.deriv_units,
            influence_sparsity: r.influence_sparsity,
            updated,
        };
        if let Some(tel) = self.telemetry.as_mut() {
            let latency_ns = t0.map_or(0, |t| t.elapsed().as_nanos() as u64);
            tel.on_step(&outcome, latency_ns, &self.ops);
        }
        outcome
    }

    /// Close a sequence: finish the engine's pass (BPTT's backward runs
    /// here) and fold its gradient into the session accumulator. Under
    /// [`UpdatePolicy::EndOfSequence`] the update is applied immediately;
    /// under [`UpdatePolicy::EveryKSteps`] any pending remainder is applied;
    /// under [`UpdatePolicy::Manual`] the caller applies later.
    pub fn end_sequence(&mut self) {
        match self.policy {
            UpdatePolicy::Manual => self.harvest(),
            UpdatePolicy::EndOfSequence => {
                self.harvest();
                self.apply_update(1.0);
            }
            UpdatePolicy::EveryKSteps(_) => {
                if self.pending_supervised > 0 {
                    self.harvest();
                    self.apply_update(1.0);
                }
            }
        }
    }

    /// Force an update right now regardless of policy (`!update` stream
    /// directive): harvest the engine gradient and apply it unscaled.
    pub fn update_now(&mut self) {
        self.harvest();
        self.apply_update(1.0);
    }

    /// Materialize the engine's accumulated gradient into `grad_accum` and
    /// clear the engine-side accumulators (influence/temporal state is
    /// untouched — that is the online regime).
    fn harvest(&mut self) {
        self.engine.end_sequence(&self.net, &mut self.readout, &mut self.ops);
        for (g, eg) in self.grad_accum.iter_mut().zip(self.engine.grads()) {
            *g += eg;
        }
        self.engine.reset_grads();
    }

    /// Apply the harvested gradient, scaled by `scale` (the trainer passes
    /// `1/batch_size`; streaming policies pass 1). Clears the accumulators
    /// and re-zeroes masked parameters.
    pub fn apply_update(&mut self, scale: f32) {
        for g in self.grad_accum.iter_mut() {
            *g *= scale;
        }
        self.net.copy_params_into(&mut self.cell_params);
        self.opt_cell.update(&mut self.cell_params, &self.grad_accum);
        self.net.load_params(&self.cell_params);
        self.net.enforce_masks();
        self.grad_accum.iter_mut().for_each(|g| *g = 0.0);

        self.readout.scale_grads(scale);
        self.readout.copy_params_into(&mut self.readout_params);
        self.readout.copy_grads_into(&mut self.readout_grads);
        self.opt_readout.update(&mut self.readout_params, &self.readout_grads);
        self.readout.load_params(&self.readout_params);
        self.readout.zero_grads();
        self.ops.macs(Phase::Optimizer, (self.net.p() + self.readout.param_len()) as u64);
        self.updates_applied += 1;
        self.pending_supervised = 0;
    }

    /// Engine state memory in words (Table-1 memory column) — constant in
    /// stream length for every online engine.
    pub fn state_memory_words(&self) -> usize {
        self.engine.state_memory_words()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;

    fn tiny_builder() -> SessionBuilder {
        let mut cfg = ExperimentConfig::default();
        cfg.model.hidden = 8;
        cfg.train.lr = 0.01;
        SessionBuilder::from_config(cfg)
    }

    /// Inputs that make a 2-in session tick; supervise every third step.
    fn drive(s: &mut OnlineSession, n: usize, seed: u64) -> Vec<StepOutcome> {
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|i| {
                let x = [rng.normal(), rng.normal()];
                let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
                s.step(&x, t)
            })
            .collect()
    }

    #[test]
    fn every_k_policy_updates_on_supervised_cadence() {
        let mut s = tiny_builder().policy(UpdatePolicy::EveryKSteps(2)).build();
        let outs = drive(&mut s, 12, 5);
        // supervised steps at i = 2,5,8,11 → updates after the 2nd and 4th
        let updated: Vec<usize> =
            outs.iter().enumerate().filter(|(_, o)| o.updated).map(|(i, _)| i).collect();
        assert_eq!(updated, vec![5, 11]);
        assert_eq!(s.updates_applied(), 2);
        assert_eq!(s.supervised_steps(), 4);
        assert_eq!(s.steps(), 12);
    }

    #[test]
    fn manual_policy_never_auto_updates() {
        let mut s = tiny_builder().policy(UpdatePolicy::Manual).build();
        let outs = drive(&mut s, 9, 6);
        assert!(outs.iter().all(|o| !o.updated));
        assert_eq!(s.updates_applied(), 0);
        s.end_sequence(); // harvest only
        assert_eq!(s.updates_applied(), 0);
        s.apply_update(0.5);
        assert_eq!(s.updates_applied(), 1);
    }

    #[test]
    fn end_of_sequence_policy_applies_at_boundary() {
        let mut s = tiny_builder().policy(UpdatePolicy::EndOfSequence).build();
        drive(&mut s, 6, 7);
        assert_eq!(s.updates_applied(), 0);
        s.end_sequence();
        assert_eq!(s.updates_applied(), 1);
    }

    #[test]
    fn predict_always_emits_predictions_on_unsupervised_steps() {
        let mut s = tiny_builder().predict_always(true).build();
        let outs = drive(&mut s, 6, 8);
        assert!(outs.iter().all(|o| o.prediction.is_some()));
        let mut s2 = tiny_builder().build();
        let outs2 = drive(&mut s2, 6, 8);
        assert!(outs2.iter().any(|o| o.prediction.is_none()));
        // the extra readout forwards cost ops
        assert!(s.ops.total_macs() > s2.ops.total_macs());
    }

    /// Satellite contract: the chainable [`SessionBuilder::threads`] and the
    /// post-build [`OnlineSession::set_threads`] are the same knob — and a
    /// pure wall-clock knob at that, so any thread count produces
    /// bit-identical outcomes to the serial default.
    #[test]
    fn builder_threads_matches_set_threads_bit_exactly() {
        let via_builder = {
            let mut s = tiny_builder()
                .algorithm(AlgorithmKind::RtrlBoth)
                .policy(UpdatePolicy::EveryKSteps(1))
                .threads(3)
                .build();
            drive(&mut s, 18, 11)
        };
        let via_setter = {
            let mut s = tiny_builder()
                .algorithm(AlgorithmKind::RtrlBoth)
                .policy(UpdatePolicy::EveryKSteps(1))
                .build();
            s.set_threads(3);
            drive(&mut s, 18, 11)
        };
        let serial = {
            let mut s = tiny_builder()
                .algorithm(AlgorithmKind::RtrlBoth)
                .policy(UpdatePolicy::EveryKSteps(1))
                .build();
            drive(&mut s, 18, 11)
        };
        let bits = |outs: &[StepOutcome]| {
            outs.iter()
                .map(|o| (o.step, o.loss.map(f32::to_bits), o.prediction, o.updated))
                .collect::<Vec<_>>()
        };
        assert_eq!(bits(&via_builder), bits(&via_setter), "builder vs setter diverged");
        assert_eq!(bits(&via_builder), bits(&serial), "threads changed results");
    }

    /// The online loop actually learns: on a fixed-association stream the
    /// loss trend goes down (same smoke-level bar the trainer tests use).
    #[test]
    fn online_updates_reduce_loss_on_learnable_stream() {
        let mut s = tiny_builder()
            .algorithm(AlgorithmKind::RtrlBoth)
            .lr(0.02)
            .policy(UpdatePolicy::EveryKSteps(1))
            .build();
        let mut early = 0.0f64;
        let mut late = 0.0f64;
        let (mut n_early, mut n_late) = (0u32, 0u32);
        let mut rng = Pcg64::new(9);
        for i in 0..400 {
            // class = sign of the first input — learnable from one step
            let x = [if rng.bernoulli(0.5) { 1.0 } else { -1.0 }, 0.5];
            let class = usize::from(x[0] > 0.0);
            let o = s.step(&x, Target::Class(class));
            let l = o.loss.unwrap() as f64;
            if i < 100 {
                early += l;
                n_early += 1;
            } else if i >= 300 {
                late += l;
                n_late += 1;
            }
        }
        assert!(
            late / n_late as f64 <= early / n_early as f64,
            "online loss did not improve: early {early} late {late}"
        );
    }

    #[test]
    fn builder_overrides_apply() {
        let s = tiny_builder()
            .algorithm(AlgorithmKind::Snap1)
            .hidden(6)
            .layers(2)
            .param_sparsity(0.5)
            .seed(11)
            .build();
        assert_eq!(s.engine().name(), "snap1");
        assert_eq!(s.net().layers(), 2);
        assert_eq!(s.net().top_n(), 6);
        assert!(s.net().layer(0).mask().is_some());
    }

    #[test]
    #[should_panic]
    fn wrong_input_width_panics() {
        let mut s = tiny_builder().build();
        s.step(&[1.0], Target::None);
    }

    #[test]
    #[should_panic]
    fn zero_update_cadence_is_a_loud_error() {
        let _ = tiny_builder().policy(UpdatePolicy::EveryKSteps(0));
    }
}
