//! Event-stream ingestion for the `stream` CLI subcommand — three wire
//! formats behind one [`EventFormat`] dispatch.
//!
//! **Text** (the original format, behavior pinned): one event per line,
//! `#` starts a comment, blank lines are skipped:
//!
//! ```text
//! 0.5 -0.2            # unsupervised input (n_in whitespace-separated floats)
//! 0.5 -0.2 -> 1       # input with a class target
//! 0.5 -0.2 -> 0.5 0.25 # input with a regression (vector) target
//! !update             # force a parameter update now (manual policy)
//! !end                # sequence boundary (end_sequence + begin_sequence)
//! ```
//!
//! After `->`, a bare unsigned integer (`1`, `42`) is a **class** target;
//! anything in decimal form (`1.0`, `0.5`, `-1`) or more than one number is
//! a **vector** (regression) target — so `-> 1` and `-> 1.0` are
//! deliberately different events.
//!
//! **JSON lines**: one JSON object per line, self-describing targets (no
//! integer/float ambiguity):
//!
//! ```text
//! {"x": [0.5, -0.2]}
//! {"x": [0.5, -0.2], "class": 1}
//! {"x": [0.5, -0.2], "target": [0.5, 0.25]}
//! {"event": "update"}
//! {"event": "end"}
//! ```
//!
//! **Binary**: an 8-byte magic (`SRTLEVS1`) then raw little-endian f32
//! frames — the zero-parse path for high-rate producers. Each frame is a
//! `u8` record tag (0 step, 1 update, 2 end); step frames carry
//! `u32 LE` input count, the inputs as LE f32 bit patterns, a `u8` target
//! kind (0 none, 1 class, 2 vector), then a `u64 LE` class or a
//! `u32 LE`-counted f32 vector. [`encode_binary`] is the reference writer.
//!
//! [`EventReader`] wraps any [`BufRead`] source, autodetects the format
//! from the leading bytes ([`EventFormat::detect`]) and yields
//! `Result<StreamEvent, EventError>` — every error carries an
//! [`EventPosition`]: the 1-based line for the line-oriented formats
//! (`file:line: message` reports), or the 1-based frame index *plus the
//! byte offset of the frame's first byte* for binary streams, where a line
//! number would be meaningless.

use crate::bench::json::{parse as json_parse, Json};
use crate::data::StepTarget;
use std::fmt;
use std::io::BufRead;

/// One parsed stream event.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// A timestep: input vector plus optional supervision.
    Step { x: Vec<f32>, target: StepTarget },
    /// Force an immediate parameter update.
    Update,
    /// Sequence boundary.
    EndSequence,
}

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// What went wrong with one event record (no position — [`EventError`]
/// adds the line number).
#[derive(Debug, Clone, PartialEq)]
pub enum EventErrorKind {
    /// A text input token failed to parse as a float.
    BadValue { token: String },
    /// The target after `->` (or in a JSON object) is invalid.
    BadTarget { detail: String },
    /// An event line has no input values.
    EmptyInput,
    /// A `!directive` other than `!update` / `!end`.
    UnknownDirective { directive: String },
    /// A JSON line failed to parse or has the wrong shape.
    Json { detail: String },
    /// A binary frame is truncated or structurally invalid.
    BadFrame { detail: String },
    /// The underlying reader failed (I/O error, non-UTF-8 text line).
    Io { detail: String },
}

impl fmt::Display for EventErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EventErrorKind::BadValue { token } => write!(f, "bad input value {token:?}"),
            EventErrorKind::BadTarget { detail } => write!(f, "bad target: {detail}"),
            EventErrorKind::EmptyInput => write!(f, "event line has no input values"),
            EventErrorKind::UnknownDirective { directive } => {
                write!(f, "unknown directive {directive:?} (try !update or !end)")
            }
            EventErrorKind::Json { detail } => write!(f, "bad json event: {detail}"),
            EventErrorKind::BadFrame { detail } => write!(f, "bad binary frame: {detail}"),
            EventErrorKind::Io { detail } => write!(f, "read failed: {detail}"),
        }
    }
}

/// Where in the stream a record sits, in the coordinates native to its
/// format: a line number for text/JSON-lines, a frame index plus the byte
/// offset of the frame's first byte for binary (seekable with `dd`/hexdump,
/// which a "line" of a binary file is not).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventPosition {
    /// 1-based line in a line-oriented stream.
    Line(u64),
    /// 1-based frame in a binary stream. `byte_offset` points at the
    /// frame's record tag (offset 0 = the stream magic, for errors in the
    /// magic itself).
    Frame { index: u64, byte_offset: u64 },
}

impl fmt::Display for EventPosition {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EventPosition::Line(n) => write!(f, "line {n}"),
            EventPosition::Frame { index, byte_offset } => {
                write!(f, "frame {index} (byte {byte_offset})")
            }
        }
    }
}

impl EventPosition {
    /// The CLI report prefix. Lines keep the grep-able `file:line` shape;
    /// frames read `file: frame N (byte B)`.
    pub fn in_file(&self, file: &str) -> String {
        match self {
            EventPosition::Line(n) => format!("{file}:{n}"),
            EventPosition::Frame { .. } => format!("{file}: {self}"),
        }
    }
}

/// An [`EventErrorKind`] at an [`EventPosition`]. Displays as
/// `line N: message` or `frame N (byte B): message`; the CLI prepends the
/// file name via [`EventError::in_file`].
#[derive(Debug, Clone, PartialEq)]
pub struct EventError {
    pub pos: EventPosition,
    pub kind: EventErrorKind,
}

impl fmt::Display for EventError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.pos, self.kind)
    }
}

impl std::error::Error for EventError {}

impl EventError {
    /// The CLI report form: `file:line: message` (text/jsonl) or
    /// `file: frame N (byte B): message` (binary).
    pub fn in_file(&self, file: &str) -> String {
        format!("{}: {}", self.pos.in_file(file), self.kind)
    }
}

// ---------------------------------------------------------------------
// Formats
// ---------------------------------------------------------------------

/// Leading magic of a binary event stream (distinct from the snapshot
/// magic, and not valid UTF-8-decimal text, so detection is unambiguous).
pub const BINARY_MAGIC: [u8; 8] = *b"SRTLEVS1";

/// Sanity cap on per-frame element counts in the binary format: a
/// corrupted count fails loudly instead of attempting a huge allocation.
const MAX_FRAME_ELEMS: u32 = 1 << 20;

/// The event-stream wire formats the `stream` subcommand accepts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventFormat {
    /// Line-oriented text (`0.5 -0.2 -> 1`, `!update`, `!end`).
    Text,
    /// One JSON object per line (`{"x": [...], "class": 1}`).
    JsonLines,
    /// Magic + raw little-endian f32 frames.
    Binary,
}

impl EventFormat {
    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            EventFormat::Text => "text",
            EventFormat::JsonLines => "jsonl",
            EventFormat::Binary => "binary",
        }
    }

    /// Inverse of [`EventFormat::name`].
    pub fn from_name(name: &str) -> Option<EventFormat> {
        match name {
            "text" => Some(EventFormat::Text),
            "jsonl" => Some(EventFormat::JsonLines),
            "binary" => Some(EventFormat::Binary),
            _ => None,
        }
    }

    /// Every format, registry-style (CLI error messages).
    pub fn all() -> [EventFormat; 3] {
        [EventFormat::Text, EventFormat::JsonLines, EventFormat::Binary]
    }

    /// Identify the format from the stream's leading bytes: the binary
    /// magic wins, a leading `{` means JSON lines, anything else is text
    /// (text is the lenient fallback — it reports its own errors per line).
    pub fn detect(prefix: &[u8]) -> EventFormat {
        if prefix.starts_with(&BINARY_MAGIC) {
            return EventFormat::Binary;
        }
        match prefix.iter().find(|b| !b.is_ascii_whitespace()) {
            Some(&b'{') => EventFormat::JsonLines,
            _ => EventFormat::Text,
        }
    }
}

impl fmt::Display for EventFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------
// Text format
// ---------------------------------------------------------------------

/// Whether a target token selects the **class** interpretation: a bare
/// unsigned integer (`1`, `42`). Decimal/signed/exponent forms (`1.0`,
/// `-1`, `5e-1`) are vector components.
fn is_class_token(tok: &str) -> bool {
    !tok.is_empty() && tok.bytes().all(|b| b.is_ascii_digit())
}

fn parse_target_tokens(spec: &str) -> Result<StepTarget, EventErrorKind> {
    let toks: Vec<&str> = spec.split_whitespace().collect();
    let bad = |detail: String| EventErrorKind::BadTarget { detail };
    match toks.as_slice() {
        [] => Err(bad("nothing after \"->\"".into())),
        [tok] if is_class_token(tok) => tok
            .parse::<usize>()
            .map(StepTarget::Class)
            .map_err(|_| bad(format!("class {tok:?} out of range"))),
        toks => toks
            .iter()
            .map(|tok| {
                tok.parse::<f32>()
                    .map_err(|_| bad(format!("cannot parse {tok:?} as a number")))
            })
            .collect::<Result<Vec<f32>, _>>()
            .map(StepTarget::Vector),
    }
}

/// Parse one **text** line. `Ok(None)` for blank/comment lines; the error
/// carries no position (the caller — [`EventReader`] — knows the line).
pub fn parse_event(line: &str) -> Result<Option<StreamEvent>, EventErrorKind> {
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    match line {
        "!update" => return Ok(Some(StreamEvent::Update)),
        "!end" => return Ok(Some(StreamEvent::EndSequence)),
        other if other.starts_with('!') => {
            return Err(EventErrorKind::UnknownDirective { directive: other.to_string() })
        }
        _ => {}
    }
    let (xpart, tpart) = match line.split_once("->") {
        Some((a, b)) => (a, Some(b.trim())),
        None => (line, None),
    };
    let x = xpart
        .split_whitespace()
        .map(|tok| {
            tok.parse::<f32>().map_err(|_| EventErrorKind::BadValue { token: tok.to_string() })
        })
        .collect::<Result<Vec<f32>, EventErrorKind>>()?;
    if x.is_empty() {
        return Err(EventErrorKind::EmptyInput);
    }
    let target = match tpart {
        None => StepTarget::None,
        Some(t) => parse_target_tokens(t)?,
    };
    Ok(Some(StreamEvent::Step { x, target }))
}

// ---------------------------------------------------------------------
// JSON-lines format
// ---------------------------------------------------------------------

fn f32s_from_json(arr: &Json, what: &str) -> Result<Vec<f32>, EventErrorKind> {
    arr.as_arr()
        .ok_or_else(|| EventErrorKind::Json { detail: format!("{what} must be an array") })?
        .iter()
        .map(|v| {
            v.as_f64().map(|x| x as f32).ok_or_else(|| EventErrorKind::Json {
                detail: format!("{what} holds a non-number"),
            })
        })
        .collect()
}

/// Parse one **JSON-lines** record. `Ok(None)` for blank lines.
pub fn parse_jsonl_event(line: &str) -> Result<Option<StreamEvent>, EventErrorKind> {
    if line.trim().is_empty() {
        return Ok(None);
    }
    let doc = json_parse(line.trim()).map_err(|e| EventErrorKind::Json { detail: e })?;
    if let Some(ev) = doc.get("event") {
        return match ev.as_str() {
            Some("update") => Ok(Some(StreamEvent::Update)),
            Some("end") => Ok(Some(StreamEvent::EndSequence)),
            _ => Err(EventErrorKind::Json {
                detail: "\"event\" must be \"update\" or \"end\"".into(),
            }),
        };
    }
    let x = f32s_from_json(
        doc.get("x").ok_or(EventErrorKind::Json {
            detail: "object needs \"x\" (a step) or \"event\" (a directive)".into(),
        })?,
        "\"x\"",
    )?;
    if x.is_empty() {
        return Err(EventErrorKind::EmptyInput);
    }
    let target = match (doc.get("class"), doc.get("target")) {
        (Some(_), Some(_)) => {
            return Err(EventErrorKind::BadTarget {
                detail: "\"class\" and \"target\" are mutually exclusive".into(),
            })
        }
        (Some(c), None) => StepTarget::Class(c.as_u64().ok_or_else(|| {
            EventErrorKind::BadTarget { detail: "\"class\" must be an unsigned integer".into() }
        })? as usize),
        (None, Some(t)) => StepTarget::Vector(f32s_from_json(t, "\"target\"")?),
        (None, None) => StepTarget::None,
    };
    Ok(Some(StreamEvent::Step { x, target }))
}

// ---------------------------------------------------------------------
// Binary format
// ---------------------------------------------------------------------

/// Append one event as a binary frame (no magic — see [`encode_binary`]).
pub fn write_event_binary(out: &mut Vec<u8>, ev: &StreamEvent) {
    match ev {
        StreamEvent::Update => out.push(1),
        StreamEvent::EndSequence => out.push(2),
        StreamEvent::Step { x, target } => {
            out.push(0);
            out.extend_from_slice(&(x.len() as u32).to_le_bytes());
            for v in x {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
            match target {
                StepTarget::None => out.push(0),
                StepTarget::Class(c) => {
                    out.push(1);
                    out.extend_from_slice(&(*c as u64).to_le_bytes());
                }
                StepTarget::Vector(t) => {
                    out.push(2);
                    out.extend_from_slice(&(t.len() as u32).to_le_bytes());
                    for v in t {
                        out.extend_from_slice(&v.to_bits().to_le_bytes());
                    }
                }
            }
        }
    }
}

/// Reference writer for the binary event format: magic + one frame per
/// event. f32s travel as bit patterns, so a text→binary→parse round trip
/// is bit-exact.
pub fn encode_binary(events: &[StreamEvent]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 16 * events.len());
    out.extend_from_slice(&BINARY_MAGIC);
    for ev in events {
        write_event_binary(&mut out, ev);
    }
    out
}

/// Decode a whole in-memory payload — format-autodetected exactly like a
/// stream ([`EventFormat::detect`]) — into its events, all-or-nothing: one
/// malformed record rejects the entire payload. The serve ingestion path
/// uses this for transactional enqueues (a tenant's frame either queues
/// completely or not at all); an empty payload is simply zero events.
pub fn parse_payload(bytes: &[u8]) -> Result<Vec<StreamEvent>, EventError> {
    let reader = match EventReader::autodetect(std::io::Cursor::new(bytes)) {
        Ok(r) => r,
        Err(e) => {
            return Err(EventError {
                pos: EventPosition::Line(0),
                kind: EventErrorKind::Io { detail: e.to_string() },
            })
        }
    };
    reader.collect()
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

/// Format-dispatching event reader over any [`BufRead`] source — the one
/// ingestion path the `stream` subcommand uses for files and stdin.
///
/// Iterates `Result<StreamEvent, EventError>`; blank/comment records are
/// skipped, and errors carry an [`EventPosition`] — the 1-based line for
/// text/jsonl, the 1-based frame index plus its starting byte offset for
/// binary. Iteration ends at EOF or after the first error.
pub struct EventReader<R: BufRead> {
    src: R,
    format: EventFormat,
    /// 1-based line (text/jsonl) or frame (binary) most recently read.
    line: u64,
    /// Binary: total bytes consumed from the source so far.
    bytes_read: u64,
    /// Binary: byte offset of the current frame's first byte (its tag).
    frame_start: u64,
    /// Binary: magic already consumed?
    started: bool,
    failed: bool,
}

impl<R: BufRead> EventReader<R> {
    /// Read events of a known format.
    pub fn new(src: R, format: EventFormat) -> Self {
        EventReader {
            src,
            format,
            line: 0,
            bytes_read: 0,
            frame_start: 0,
            started: false,
            failed: false,
        }
    }

    /// Sniff the format from the stream's first buffered bytes, then read.
    pub fn autodetect(mut src: R) -> std::io::Result<Self> {
        let format = EventFormat::detect(src.fill_buf()?);
        Ok(Self::new(src, format))
    }

    /// The format this reader is decoding.
    pub fn format(&self) -> EventFormat {
        self.format
    }

    /// Position of the record most recently yielded — for reports about
    /// events that parsed but are invalid for the consumer (e.g. wrong
    /// input width). Lines for text/jsonl, frame + byte offset for binary.
    pub fn pos(&self) -> EventPosition {
        match self.format {
            EventFormat::Binary => {
                EventPosition::Frame { index: self.line.max(1), byte_offset: self.frame_start }
            }
            EventFormat::Text | EventFormat::JsonLines => EventPosition::Line(self.line.max(1)),
        }
    }

    fn err(&mut self, kind: EventErrorKind) -> Option<Result<StreamEvent, EventError>> {
        self.failed = true;
        Some(Err(EventError { pos: self.pos(), kind }))
    }

    fn next_line(&mut self) -> Result<Option<String>, EventErrorKind> {
        let mut buf = String::new();
        match self.src.read_line(&mut buf) {
            Ok(0) => Ok(None),
            Ok(_) => {
                self.line += 1;
                Ok(Some(buf))
            }
            Err(e) => {
                self.line += 1; // the failing line
                Err(EventErrorKind::Io { detail: e.to_string() })
            }
        }
    }

    fn read_exact(&mut self, buf: &mut [u8]) -> Result<(), EventErrorKind> {
        use std::io::Read;
        match self.src.read_exact(buf) {
            Ok(()) => {
                self.bytes_read += buf.len() as u64;
                Ok(())
            }
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                Err(EventErrorKind::BadFrame { detail: "truncated frame".into() })
            }
            Err(e) => Err(EventErrorKind::Io { detail: e.to_string() }),
        }
    }

    fn read_u32(&mut self) -> Result<u32, EventErrorKind> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn read_f32s(&mut self, what: &str) -> Result<Vec<f32>, EventErrorKind> {
        let n = self.read_u32()?;
        if n == 0 || n > MAX_FRAME_ELEMS {
            return Err(EventErrorKind::BadFrame {
                detail: format!("{what} count {n} outside 1..={MAX_FRAME_ELEMS}"),
            });
        }
        let mut out = Vec::with_capacity(n as usize);
        let mut b = [0u8; 4];
        for _ in 0..n {
            self.read_exact(&mut b)?;
            out.push(f32::from_bits(u32::from_le_bytes(b)));
        }
        Ok(out)
    }

    fn next_binary(&mut self) -> Result<Option<StreamEvent>, EventErrorKind> {
        use std::io::Read;
        if !self.started {
            let mut magic = [0u8; 8];
            // a bad/short magic reports as frame 1 at byte 0
            self.line = 1;
            self.frame_start = 0;
            self.read_exact(&mut magic)?;
            if magic != BINARY_MAGIC {
                return Err(EventErrorKind::BadFrame {
                    detail: "stream does not start with the event magic".into(),
                });
            }
            self.started = true;
            self.line = 0;
        }
        self.frame_start = self.bytes_read;
        let mut tag = [0u8; 1];
        // EOF at a frame boundary is the clean end of the stream
        match self.src.read(&mut tag) {
            Ok(0) => return Ok(None),
            Ok(_) => {}
            Err(e) => return Err(EventErrorKind::Io { detail: e.to_string() }),
        }
        self.bytes_read += 1;
        self.line += 1;
        match tag[0] {
            1 => Ok(Some(StreamEvent::Update)),
            2 => Ok(Some(StreamEvent::EndSequence)),
            0 => {
                let x = self.read_f32s("input")?;
                let mut tkind = [0u8; 1];
                self.read_exact(&mut tkind)?;
                let target = match tkind[0] {
                    0 => StepTarget::None,
                    1 => {
                        let mut b = [0u8; 8];
                        self.read_exact(&mut b)?;
                        let c = u64::from_le_bytes(b);
                        usize::try_from(c)
                            .map(StepTarget::Class)
                            .map_err(|_| EventErrorKind::BadTarget {
                                detail: format!("class {c} out of range"),
                            })?
                    }
                    2 => StepTarget::Vector(self.read_f32s("target")?),
                    k => {
                        return Err(EventErrorKind::BadFrame {
                            detail: format!("unknown target kind {k}"),
                        })
                    }
                };
                Ok(Some(StreamEvent::Step { x, target }))
            }
            t => Err(EventErrorKind::BadFrame { detail: format!("unknown record tag {t}") }),
        }
    }
}

impl<R: BufRead> Iterator for EventReader<R> {
    type Item = Result<StreamEvent, EventError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.failed {
            return None;
        }
        loop {
            match self.format {
                EventFormat::Binary => {
                    return match self.next_binary() {
                        Ok(Some(ev)) => Some(Ok(ev)),
                        Ok(None) => None,
                        Err(kind) => self.err(kind),
                    }
                }
                EventFormat::Text | EventFormat::JsonLines => {
                    let line = match self.next_line() {
                        Ok(Some(line)) => line,
                        Ok(None) => return None,
                        Err(kind) => return self.err(kind),
                    };
                    let parsed = match self.format {
                        EventFormat::Text => parse_event(&line),
                        _ => parse_jsonl_event(&line),
                    };
                    match parsed {
                        Ok(Some(ev)) => return Some(Ok(ev)),
                        Ok(None) => continue, // blank/comment line
                        Err(kind) => return self.err(kind),
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(x: &[f32], target: StepTarget) -> StreamEvent {
        StreamEvent::Step { x: x.to_vec(), target }
    }

    #[test]
    fn parse_payload_autodetects_and_is_all_or_nothing() {
        // text payload
        let evs = parse_payload(b"0.5 -0.2\n!update\n0.1 0.3 -> 1\n").unwrap();
        assert_eq!(
            evs,
            vec![
                step(&[0.5, -0.2], StepTarget::None),
                StreamEvent::Update,
                step(&[0.1, 0.3], StepTarget::Class(1)),
            ]
        );
        // binary payload round-trips bit-exactly
        assert_eq!(parse_payload(&encode_binary(&evs)).unwrap(), evs);
        // jsonl payload
        let evs2 = parse_payload(b"{\"x\": [1.0, 2.0], \"class\": 0}\n").unwrap();
        assert_eq!(evs2, vec![step(&[1.0, 2.0], StepTarget::Class(0))]);
        // empty payload is zero events, not an error
        assert_eq!(parse_payload(b"").unwrap(), vec![]);
        // one bad record rejects the whole payload
        let err = parse_payload(b"0.5 -0.2\nnot-a-number\n").unwrap_err();
        assert_eq!(err.pos, EventPosition::Line(2));
        assert!(matches!(err.kind, EventErrorKind::BadValue { .. }));
    }

    #[test]
    fn parses_steps_targets_and_directives() {
        assert_eq!(parse_event("").unwrap(), None);
        assert_eq!(parse_event("  # just a comment").unwrap(), None);
        assert_eq!(
            parse_event("0.5 -0.2").unwrap(),
            Some(step(&[0.5, -0.2], StepTarget::None))
        );
        assert_eq!(
            parse_event("1.0 2.0 -> 1  # recall").unwrap(),
            Some(step(&[1.0, 2.0], StepTarget::Class(1)))
        );
        assert_eq!(parse_event("!update").unwrap(), Some(StreamEvent::Update));
        assert_eq!(parse_event("!end").unwrap(), Some(StreamEvent::EndSequence));
    }

    #[test]
    fn regression_targets_parse_as_vectors() {
        assert_eq!(
            parse_event("0.5 -0.2 -> 0.5 0.25").unwrap(),
            Some(step(&[0.5, -0.2], StepTarget::Vector(vec![0.5, 0.25])))
        );
        // ambiguous single number: integer form is a class…
        assert_eq!(
            parse_event("1.0 -> 2").unwrap(),
            Some(step(&[1.0], StepTarget::Class(2)))
        );
        // …while decimal / signed / exponent forms are one-element vectors
        assert_eq!(
            parse_event("1.0 -> 2.0").unwrap(),
            Some(step(&[1.0], StepTarget::Vector(vec![2.0])))
        );
        assert_eq!(
            parse_event("1.0 -> -1").unwrap(),
            Some(step(&[1.0], StepTarget::Vector(vec![-1.0])))
        );
        assert_eq!(
            parse_event("1.0 -> 5e-1").unwrap(),
            Some(step(&[1.0], StepTarget::Vector(vec![0.5])))
        );
    }

    #[test]
    fn malformed_lines_error_with_typed_kinds() {
        assert!(matches!(parse_event("abc"), Err(EventErrorKind::BadValue { .. })));
        assert!(matches!(parse_event("0.5 -> x"), Err(EventErrorKind::BadTarget { .. })));
        assert!(matches!(parse_event("-> 1"), Err(EventErrorKind::EmptyInput)));
        assert!(matches!(parse_event("0.5 ->"), Err(EventErrorKind::BadTarget { .. })));
        assert!(matches!(
            parse_event("!frobnicate"),
            Err(EventErrorKind::UnknownDirective { .. })
        ));
    }

    #[test]
    fn jsonl_events_parse() {
        assert_eq!(parse_jsonl_event("   ").unwrap(), None);
        assert_eq!(
            parse_jsonl_event(r#"{"x": [0.5, -0.2]}"#).unwrap(),
            Some(step(&[0.5, -0.2], StepTarget::None))
        );
        assert_eq!(
            parse_jsonl_event(r#"{"x": [1.0], "class": 3}"#).unwrap(),
            Some(step(&[1.0], StepTarget::Class(3)))
        );
        assert_eq!(
            parse_jsonl_event(r#"{"x": [1.0], "target": [0.5, 0.25]}"#).unwrap(),
            Some(step(&[1.0], StepTarget::Vector(vec![0.5, 0.25])))
        );
        assert_eq!(parse_jsonl_event(r#"{"event": "update"}"#).unwrap(), Some(StreamEvent::Update));
        assert_eq!(parse_jsonl_event(r#"{"event": "end"}"#).unwrap(), Some(StreamEvent::EndSequence));
        assert!(matches!(parse_jsonl_event("{"), Err(EventErrorKind::Json { .. })));
        assert!(matches!(parse_jsonl_event(r#"{"y": 1}"#), Err(EventErrorKind::Json { .. })));
        assert!(matches!(
            parse_jsonl_event(r#"{"x": [1.0], "class": 1, "target": [2.0]}"#),
            Err(EventErrorKind::BadTarget { .. })
        ));
        assert!(matches!(
            parse_jsonl_event(r#"{"event": "frobnicate"}"#),
            Err(EventErrorKind::Json { .. })
        ));
    }

    #[test]
    fn format_detection() {
        assert_eq!(EventFormat::detect(&encode_binary(&[])), EventFormat::Binary);
        assert_eq!(EventFormat::detect(b"  {\"x\": [1]}"), EventFormat::JsonLines);
        assert_eq!(EventFormat::detect(b"0.5 -0.2 -> 1"), EventFormat::Text);
        assert_eq!(EventFormat::detect(b""), EventFormat::Text);
        for f in EventFormat::all() {
            assert_eq!(EventFormat::from_name(f.name()), Some(f));
        }
        assert_eq!(EventFormat::from_name("csv"), None);
    }

    fn sample_events() -> Vec<StreamEvent> {
        vec![
            step(&[0.5, -0.2], StepTarget::None),
            step(&[1.0, 2.0], StepTarget::Class(1)),
            step(&[-0.0, f32::MIN_POSITIVE], StepTarget::Vector(vec![0.5, 0.25])),
            StreamEvent::Update,
            StreamEvent::EndSequence,
        ]
    }

    /// The three formats describe the same stream: binary and jsonl
    /// renderings of the same events parse back identically (bit-exact for
    /// binary, which carries f32 bit patterns).
    #[test]
    fn binary_round_trip_is_bit_exact() {
        let events = sample_events();
        let bytes = encode_binary(&events);
        let reader = EventReader::autodetect(std::io::Cursor::new(&bytes)).unwrap();
        assert_eq!(reader.format(), EventFormat::Binary);
        let back: Vec<StreamEvent> = reader.map(|r| r.unwrap()).collect();
        assert_eq!(back, events);
        // -0.0 survived as -0.0
        match &back[2] {
            StreamEvent::Step { x, .. } => assert_eq!(x[0].to_bits(), (-0.0f32).to_bits()),
            other => panic!("expected a step, got {other:?}"),
        }
    }

    #[test]
    fn reader_reports_line_numbers() {
        let text = "0.5 -0.2\n# comment\n\n0.5 bad\n";
        let mut reader =
            EventReader::new(std::io::Cursor::new(text.as_bytes()), EventFormat::Text);
        assert!(reader.next().unwrap().is_ok());
        let err = reader.next().unwrap().unwrap_err();
        assert_eq!(err.pos, EventPosition::Line(4), "comment/blank lines still count for positions");
        assert!(matches!(err.kind, EventErrorKind::BadValue { .. }));
        assert_eq!(err.in_file("events.txt"), format!("events.txt:4: {}", err.kind));
        assert!(reader.next().is_none(), "iteration stops after an error");
    }

    #[test]
    fn truncated_binary_frame_is_a_typed_error() {
        let mut bytes = encode_binary(&sample_events());
        bytes.truncate(bytes.len() - 3);
        let errs: Vec<_> = EventReader::autodetect(std::io::Cursor::new(&bytes))
            .unwrap()
            .filter_map(|r| r.err())
            .collect();
        assert_eq!(errs.len(), 1);
        assert!(matches!(errs[0].kind, EventErrorKind::BadFrame { .. }), "{:?}", errs[0]);
    }

    /// Regression: binary errors must carry the frame index **and** the
    /// byte offset of the frame's first byte — not a meaningless "line".
    /// Offsets are computed from the reference writer, so this stays in
    /// sync with the wire format.
    #[test]
    fn binary_errors_carry_frame_index_and_byte_offset() {
        let events = sample_events();
        // byte offset where each frame starts: magic, then cumulative sizes
        let mut offsets = Vec::with_capacity(events.len());
        let mut frame = Vec::new();
        let mut at = BINARY_MAGIC.len() as u64;
        for ev in &events {
            offsets.push(at);
            frame.clear();
            write_event_binary(&mut frame, ev);
            at += frame.len() as u64;
        }

        // truncate inside the 3rd frame (the vector-target step)
        let mut bytes = encode_binary(&events);
        bytes.truncate(offsets[2] as usize + 5);
        let err = EventReader::autodetect(std::io::Cursor::new(&bytes))
            .unwrap()
            .filter_map(|r| r.err())
            .next()
            .unwrap();
        assert!(matches!(err.kind, EventErrorKind::BadFrame { .. }), "{err:?}");
        assert_eq!(err.pos, EventPosition::Frame { index: 3, byte_offset: offsets[2] });
        assert_eq!(
            err.in_file("events.bin"),
            format!("events.bin: frame 3 (byte {}): {}", offsets[2], err.kind)
        );

        // a bad magic reports as frame 1 at byte 0
        let err = EventReader::new(std::io::Cursor::new(b"XXXXXXXX\x01".as_slice()), EventFormat::Binary)
            .filter_map(|r| r.err())
            .next()
            .unwrap();
        assert_eq!(err.pos, EventPosition::Frame { index: 1, byte_offset: 0 });
    }

    #[test]
    fn corrupt_binary_count_never_allocates_huge() {
        let mut bytes = encode_binary(&[step(&[1.0, 2.0], StepTarget::None)]);
        // frame starts after the 8-byte magic: tag at 8, count at 9..13
        bytes[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
        let errs: Vec<_> = EventReader::new(std::io::Cursor::new(&bytes), EventFormat::Binary)
            .filter_map(|r| r.err())
            .collect();
        assert_eq!(errs.len(), 1);
        assert!(matches!(errs[0].kind, EventErrorKind::BadFrame { .. }));
    }

    #[test]
    fn jsonl_reader_drives_a_stream() {
        let text = "{\"x\": [0.1, 0.2]}\n\n{\"x\": [0.3, 0.4], \"class\": 0}\n{\"event\": \"end\"}\n";
        let reader = EventReader::autodetect(std::io::Cursor::new(text.as_bytes())).unwrap();
        assert_eq!(reader.format(), EventFormat::JsonLines);
        let events: Vec<StreamEvent> = reader.map(|r| r.unwrap()).collect();
        assert_eq!(events.len(), 3);
        assert_eq!(events[2], StreamEvent::EndSequence);
    }
}
