//! Text event-stream format for the `stream` CLI subcommand.
//!
//! One event per line; `#` starts a comment, blank lines are skipped:
//!
//! ```text
//! 0.5 -0.2          # unsupervised input (n_in whitespace-separated floats)
//! 0.5 -0.2 -> 1     # input with a class target
//! !update           # force a parameter update now (manual policy)
//! !end              # sequence boundary (end_sequence + begin_sequence)
//! ```

use crate::data::StepTarget;

/// One parsed stream event.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// A timestep: input vector plus optional supervision.
    Step { x: Vec<f32>, target: StepTarget },
    /// Force an immediate parameter update.
    Update,
    /// Sequence boundary.
    EndSequence,
}

/// Parse one line. `Ok(None)` for blank/comment lines; `Err` carries a
/// message without the line number (the caller knows the position).
pub fn parse_event(line: &str) -> Result<Option<StreamEvent>, String> {
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    match line {
        "!update" => return Ok(Some(StreamEvent::Update)),
        "!end" => return Ok(Some(StreamEvent::EndSequence)),
        other if other.starts_with('!') => {
            return Err(format!("unknown directive {other:?} (try !update or !end)"))
        }
        _ => {}
    }
    let (xpart, tpart) = match line.split_once("->") {
        Some((a, b)) => (a, Some(b.trim())),
        None => (line, None),
    };
    let x = xpart
        .split_whitespace()
        .map(|tok| tok.parse::<f32>().map_err(|_| format!("bad input value {tok:?}")))
        .collect::<Result<Vec<f32>, String>>()?;
    if x.is_empty() {
        return Err("event line has no input values".into());
    }
    let target = match tpart {
        None => StepTarget::None,
        Some(t) => StepTarget::Class(
            t.parse::<usize>().map_err(|_| format!("bad class target {t:?}"))?,
        ),
    };
    Ok(Some(StreamEvent::Step { x, target }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_steps_targets_and_directives() {
        assert_eq!(parse_event("").unwrap(), None);
        assert_eq!(parse_event("  # just a comment").unwrap(), None);
        assert_eq!(
            parse_event("0.5 -0.2").unwrap(),
            Some(StreamEvent::Step { x: vec![0.5, -0.2], target: StepTarget::None })
        );
        assert_eq!(
            parse_event("1.0 2.0 -> 1  # recall").unwrap(),
            Some(StreamEvent::Step { x: vec![1.0, 2.0], target: StepTarget::Class(1) })
        );
        assert_eq!(parse_event("!update").unwrap(), Some(StreamEvent::Update));
        assert_eq!(parse_event("!end").unwrap(), Some(StreamEvent::EndSequence));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(parse_event("abc").is_err());
        assert!(parse_event("0.5 -> x").is_err());
        assert!(parse_event("-> 1").is_err());
        assert!(parse_event("!frobnicate").is_err());
    }
}
