//! Micro-bench of the RTRL influence-update hot path in isolation, across
//! activity levels — the L3 target of the performance pass (§Perf).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, print_table};

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{GradientEngine, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

/// One engine step (forward + influence update) at a controlled activity
/// level, achieved by tuning the threshold. `layers` adds depth: every
/// layer gets the same width and an independent mask at `density`.
fn bench_step(
    name: &str,
    kind: AlgorithmKind,
    theta: f32,
    n: usize,
    layers: usize,
    density: f32,
) -> bench_util::Sample {
    let mut rng = Pcg64::new(11);
    let mut cells = Vec::with_capacity(layers);
    for l in 0..layers {
        let n_in = if l == 0 { 2 } else { n };
        let mask = if density < 1.0 {
            Some(MaskPattern::random(n, n, density, &mut rng))
        } else {
            None
        };
        cells.push(RnnCell::egru(n, n_in, theta, 0.3, 0.4, mask, &mut rng));
    }
    let net = LayerStack::new(cells);
    let mut readout = Readout::new(2, n, &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut eng = build_engine(kind, &net, 2);
    let mut ops = OpCounter::new();
    eng.begin_sequence();
    // advance a few steps so M is populated and activity settles
    let mut xrng = Pcg64::new(5);
    for _ in 0..4 {
        let x = [xrng.normal(), xrng.normal()];
        eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
    }
    // reset every T=17 steps like real training (an endless recursion decays
    // M toward zero, which does not represent the per-sequence regime)
    let mut t = 0u32;
    bench(name, 25.0, 7, || {
        if t % 17 == 0 {
            eng.begin_sequence();
        }
        t += 1;
        let x = [xrng.normal(), xrng.normal()];
        let r = eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        bench_util::black_box(r.deriv_units);
    })
}

fn main() {
    for &n in &[16usize, 32, 64] {
        let mut samples = Vec::new();
        samples.push(bench_step("dense engine", AlgorithmKind::RtrlDense, 0.1, n, 1, 1.0));
        samples.push(bench_step("activity (θ=0.1)", AlgorithmKind::RtrlActivity, 0.1, n, 1, 1.0));
        samples.push(bench_step("activity (θ=0.3, sparser)", AlgorithmKind::RtrlActivity, 0.3, n, 1, 1.0));
        samples.push(bench_step("param ω̃=0.2", AlgorithmKind::RtrlParam, 0.1, n, 1, 0.2));
        samples.push(bench_step("both ω̃=0.2 θ=0.1", AlgorithmKind::RtrlBoth, 0.1, n, 1, 0.2));
        samples.push(bench_step("both ω̃=0.1 θ=0.3", AlgorithmKind::RtrlBoth, 0.3, n, 1, 0.1));
        print_table(&format!("RTRL influence update, one step, n={n}"), &samples);
        // depth axis: same width stacked twice — the block recursion's cost
        let depth = vec![
            bench_step("L=2 dense engine", AlgorithmKind::RtrlDense, 0.1, n, 2, 1.0),
            bench_step("L=2 activity (θ=0.1)", AlgorithmKind::RtrlActivity, 0.1, n, 2, 1.0),
            bench_step("L=2 both ω̃=0.2 θ=0.1", AlgorithmKind::RtrlBoth, 0.1, n, 2, 0.2),
        ];
        print_table(&format!("RTRL influence update, one step, n={n}, 2 layers"), &depth);
    }
}
