//! Cell forward-pass micro-bench: the `ω̃α̃n²` event-driven gather
//! (Table 1's forward term) vs dense activity.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, print_table};

use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{CellScratch, RnnCell};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::util::Pcg64;

fn bench_forward(name: &str, n: usize, density: f32, active_frac: f32) -> bench_util::Sample {
    let mut rng = Pcg64::new(3);
    let mask = if density < 1.0 {
        Some(MaskPattern::random(n, n, density, &mut rng))
    } else {
        None
    };
    let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, mask, &mut rng);
    let mut scratch = CellScratch::new(n);
    let mut ops = OpCounter::new();
    // fixed binary activation pattern at the requested activity level
    let active = (active_frac * n as f32).round() as usize;
    let mut a_prev = vec![0.0f32; n];
    for k in 0..active {
        a_prev[k] = 1.0;
    }
    let x = [0.4f32, -0.7];
    bench(name, 10.0, 7, || {
        cell.forward(&a_prev, &x, &mut scratch, &mut ops);
        bench_util::black_box(scratch.v[0]);
    })
}

fn main() {
    for &n in &[16usize, 64, 128, 256] {
        let samples = vec![
            bench_forward("dense weights, all units active", n, 1.0, 1.0),
            bench_forward("dense weights, 25% active", n, 1.0, 0.25),
            bench_forward("dense weights, 1 unit active", n, 1.0, 1.0 / n as f32),
            bench_forward("ω̃=0.2 weights, all active", n, 0.2, 1.0),
            bench_forward("ω̃=0.2 weights, 25% active", n, 0.2, 0.25),
        ];
        print_table(&format!("EGRU cell forward, n={n}"), &samples);
    }
}
