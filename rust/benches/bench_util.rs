//! Shared micro-bench harness (no criterion offline): warmup + timed runs,
//! median-of-samples reporting, and a tabular printer.

use std::time::Instant;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    #[allow(dead_code)]
    pub iters: u64,
}

/// Measure `f` (one logical operation per call). Auto-scales iteration count
/// to ~`target_ms` per sample, takes `samples` samples, reports median.
pub fn bench<F: FnMut()>(name: &str, target_ms: f64, samples: usize, mut f: F) -> Sample {
    // warmup + calibration
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = t0.elapsed().as_secs_f64() * 1e3;
        if el > target_ms || iters > (1 << 24) {
            break;
        }
        iters = (iters * 2).max(((iters as f64) * target_ms / el.max(1e-6)) as u64 + 1);
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t0.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
    Sample { name: name.to_string(), median_ns: median, mean_ns: mean, stddev_ns: var.sqrt(), iters }
}

/// Pretty-print a group of samples with a relative column.
pub fn print_table(title: &str, samples: &[Sample]) {
    println!("\n=== {title} ===");
    println!("{:<38}{:>14}{:>14}{:>10}{:>10}", "case", "median", "mean", "±σ%", "rel");
    let base = samples.first().map(|s| s.median_ns).unwrap_or(1.0);
    for s in samples {
        println!(
            "{:<38}{:>14}{:>14}{:>9.1}%{:>10.3}",
            s.name,
            fmt_ns(s.median_ns),
            fmt_ns(s.mean_ns),
            100.0 * s.stddev_ns / s.mean_ns.max(1e-9),
            s.median_ns / base
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
