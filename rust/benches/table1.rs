//! Table 1 wallclock bench: time-per-training-step for every method at the
//! paper's n=16 and at larger n, across parameter-sparsity levels.
//!
//! Prints measured wallclock + MAC counts next to the paper's analytic
//! factors — the reproduction target is the *ordering* and rough ratios,
//! not absolute numbers.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, print_table};

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::report::table1::CostParams;
use sparse_rtrl::rtrl::{GradientEngine, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

fn bench_method(kind: AlgorithmKind, cell: &RnnCell, t: usize) -> bench_util::Sample {
    let mut rng = Pcg64::new(1);
    let mut readout = Readout::new(2, cell.n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut eng = build_engine(kind, cell, 2);
    let xs: Vec<[f32; 2]> = (0..t).map(|_| [rng.normal(), rng.normal()]).collect();
    let mut ops = OpCounter::new();
    bench(kind.name(), 30.0, 7, || {
        eng.begin_sequence();
        for (i, x) in xs.iter().enumerate() {
            let target = if i + 1 == t { Target::Class(0) } else { Target::None };
            eng.step(cell, &mut readout, &mut loss, x, target, &mut ops);
        }
        eng.end_sequence(cell, &mut readout, &mut ops);
        bench_util::black_box(eng.grads()[0]);
    })
}

fn main() {
    let t = 17; // paper's sequence length
    for &(n, omega) in &[(16usize, 0.0f32), (16, 0.8), (16, 0.9), (32, 0.8), (64, 0.9)] {
        let mut rng = Pcg64::new(7);
        let mask = if omega > 0.0 {
            Some(MaskPattern::random(n, n, 1.0 - omega, &mut rng))
        } else {
            None
        };
        let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, mask, &mut rng);
        // measured sparsity for the analytic columns
        let (_, _, _, at, bt) =
            sparse_rtrl::report::table1::measure(AlgorithmKind::RtrlDense, &cell, t, 3);
        let params = CostParams {
            n,
            p: cell.p(),
            t,
            omega_tilde: cell.omega_tilde() as f64,
            alpha_tilde: at,
            beta_tilde: bt,
        };
        let mut samples = Vec::new();
        for kind in [
            AlgorithmKind::RtrlDense,
            AlgorithmKind::RtrlParam,
            AlgorithmKind::RtrlActivity,
            AlgorithmKind::RtrlBoth,
            AlgorithmKind::Snap1,
            AlgorithmKind::Snap2,
            AlgorithmKind::Bptt,
        ] {
            samples.push(bench_method(kind, &cell, t));
        }
        print_table(
            &format!(
                "Table 1 wallclock: n={n} p={} ω={omega} (ω̃={:.2} α̃={:.2} β̃={:.2}), {t}-step sequence",
                params.p, params.omega_tilde, params.alpha_tilde, params.beta_tilde
            ),
            &samples,
        );
        println!("analytic influence-update factors (MACs/step):");
        for kind in AlgorithmKind::all() {
            println!("  {:<14} {:>14.0}", kind.name(), params.analytic_influence(kind));
        }
    }
}
