//! Table 1 wallclock bench: time-per-training-step for every method at the
//! paper's n=16 and at larger n, across parameter-sparsity levels.
//!
//! Prints measured wallclock + MAC counts next to the paper's analytic
//! factors — the reproduction target is the *ordering* and rough ratios,
//! not absolute numbers.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, print_table};

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::report::table1::CostParams;
use sparse_rtrl::rtrl::{GradientEngine, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

fn bench_method(kind: AlgorithmKind, net: &LayerStack, t: usize) -> bench_util::Sample {
    let mut rng = Pcg64::new(1);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut eng = build_engine(kind, net, 2);
    let xs: Vec<[f32; 2]> = (0..t).map(|_| [rng.normal(), rng.normal()]).collect();
    let mut ops = OpCounter::new();
    bench(kind.name(), 30.0, 7, || {
        eng.begin_sequence();
        for (i, x) in xs.iter().enumerate() {
            let target = if i + 1 == t { Target::Class(0) } else { Target::None };
            eng.step(net, &mut readout, &mut loss, x, target, &mut ops);
        }
        eng.end_sequence(net, &mut readout, &mut ops);
        bench_util::black_box(eng.grads()[0]);
    })
}

fn main() {
    let t = 17; // paper's sequence length
    for &(n, layers, omega) in &[
        (16usize, 1usize, 0.0f32),
        (16, 1, 0.8),
        (16, 1, 0.9),
        (16, 2, 0.8),
        (32, 1, 0.8),
        (64, 1, 0.9),
    ] {
        let mut rng = Pcg64::new(7);
        let mut cells = Vec::with_capacity(layers);
        for l in 0..layers {
            let n_in = if l == 0 { 2 } else { n };
            let mask = if omega > 0.0 {
                Some(MaskPattern::random(n, n, 1.0 - omega, &mut rng))
            } else {
                None
            };
            cells.push(RnnCell::egru(n, n_in, 0.1, 0.3, 0.5, mask, &mut rng));
        }
        let net = LayerStack::new(cells);
        // measured sparsity for the analytic columns
        let base = sparse_rtrl::report::table1::measure(AlgorithmKind::RtrlDense, &net, t, 3);
        let params = CostParams {
            n,
            p: net.p(),
            layer_p: (0..layers).map(|l| net.layer(l).p()).collect(),
            t,
            layers,
            omega_tilde: net.omega_tilde() as f64,
            alpha_tilde: base.alpha_tilde,
            beta_tilde: base.beta_tilde,
        };
        let mut samples = Vec::new();
        for kind in [
            AlgorithmKind::RtrlDense,
            AlgorithmKind::RtrlParam,
            AlgorithmKind::RtrlActivity,
            AlgorithmKind::RtrlBoth,
            AlgorithmKind::Snap1,
            AlgorithmKind::Snap2,
            AlgorithmKind::Bptt,
        ] {
            samples.push(bench_method(kind, &net, t));
        }
        print_table(
            &format!(
                "Table 1 wallclock: n={n} L={layers} P={} ω={omega} (ω̃={:.2} α̃={:.2} β̃={:.2}), {t}-step sequence",
                params.p, params.omega_tilde, params.alpha_tilde, params.beta_tilde
            ),
            &samples,
        );
        println!("analytic influence-update factors (MACs/step):");
        for kind in AlgorithmKind::all() {
            println!("  {:<14} {:>14.0}", kind.name(), params.analytic_influence(kind));
        }
    }
}
