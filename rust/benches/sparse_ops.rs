//! Substrate micro-benches: CSR matvec vs dense matvec, row-gather patterns,
//! RowSet overheads — the building blocks whose costs Table 1 aggregates.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, black_box, print_table};

use sparse_rtrl::sparse::{Csr, MaskPattern, RowSet};
use sparse_rtrl::tensor::Matrix;
use sparse_rtrl::util::Pcg64;

fn main() {
    let mut rng = Pcg64::new(1);
    for &n in &[64usize, 256, 1024] {
        let dense_buf: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        let dense = Matrix::from_vec(n, n, dense_buf.clone());
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; n];
        let mut samples = Vec::new();
        samples.push(bench(&format!("dense matvec {n}x{n}"), 10.0, 7, || {
            dense.matvec_into(&x, &mut y);
            black_box(y[0]);
        }));
        for density in [0.5f32, 0.2, 0.1] {
            let mask = MaskPattern::random(n, n, density, &mut rng);
            let csr = Csr::from_mask(&mask, &dense_buf);
            samples.push(bench(&format!("csr matvec ω̃={density}"), 10.0, 7, || {
                csr.matvec_into(&x, &mut y);
                black_box(y[0]);
            }));
        }
        print_table(&format!("matvec substrate, n={n}"), &samples);
    }

    // RowSet traffic typical of one RTRL step
    let n = 128;
    let mut set = RowSet::empty(n);
    let pattern: Vec<usize> = (0..n).filter(|k| k % 3 != 0).collect();
    let s = bench("rowset clear+insert 2/3", 5.0, 7, || {
        set.clear();
        for &k in &pattern {
            set.insert(k);
        }
        black_box(set.len());
    });
    print_table("active-row tracking, n=128", &[s]);
}
